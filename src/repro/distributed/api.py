"""Activation-sharding context: models annotate activations with logical
axes; the distributed runtime installs a resolver mapping them to mesh axes.

Outside a mesh context ``shard_act`` is the identity, so models run unchanged
on a single device (smoke tests) and under ``jit`` without a mesh.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Callable, Optional, Sequence

import jax

_state = threading.local()


def _resolver() -> Optional[Callable]:
    return getattr(_state, "resolver", None)


def shard_act(x: jax.Array, logical: Sequence[Optional[str]]) -> jax.Array:
    """Constrain activation sharding by logical axis names (or no-op)."""
    fn = _resolver()
    if fn is None:
        return x
    return fn(x, tuple(logical))


@contextlib.contextmanager
def activation_sharding(resolver: Callable):
    """Install a resolver: (array, logical axes) -> array."""
    prev = _resolver()
    _state.resolver = resolver
    try:
        yield
    finally:
        _state.resolver = prev
