"""Logical-axis → mesh-axis sharding rules (FSDP × TP × EP × SP).

Params carry logical axes from their ParamDefs; activations carry logical
axes at shard_act call sites.  Rules map logical names to mesh axes; a
dimension whose size does not divide the mesh-axis extent is silently
replicated (e.g. 8 KV heads on a 16-way model axis), which keeps every
architecture compilable under every mesh — the autotuner then *tunes* which
rules to enable (the paper's technique applied to distribution configs).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Logical-name -> mesh axis (or tuple of axes) mapping."""

    rules: Tuple[Tuple[str, Any], ...]

    def get(self, name: Optional[str]):
        if name is None:
            return None
        for k, v in self.rules:
            if k == name:
                return v
        return None

    def replace(self, **kw) -> "ShardingRules":
        d = dict(self.rules)
        d.update(kw)
        return ShardingRules(tuple(d.items()))


def default_rules(multi_pod: bool, fsdp: bool = True,
                  tp: bool = True) -> ShardingRules:
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    model = "model" if tp else None
    return ShardingRules(tuple({
        "batch": batch_axes,
        "vocab": model,
        "heads": model,
        "kv": model,
        "mlp": model,
        "expert": model,
        "embed": "data" if fsdp else None,   # FSDP: shard params over data
        "seq": "data",                        # SP for long-context cells
        "layers": None,
    }.items()))


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def spec_for(
    mesh: Mesh, rules: ShardingRules,
    logical: Sequence[Optional[str]], shape: Sequence[int],
) -> P:
    """Build a PartitionSpec, dropping axes that do not divide evenly."""
    parts = []
    used: set = set()
    for name, dim in zip(logical, shape):
        axes = rules.get(name)
        if axes is None:
            parts.append(None)
            continue
        if isinstance(axes, str):
            axes = (axes,)
        axes = tuple(a for a in axes if a in mesh.shape and a not in used)
        size = _axis_size(mesh, axes)
        if size <= 1 or dim % size != 0:
            parts.append(None)
            continue
        used.update(axes)
        parts.append(axes if len(axes) > 1 else axes[0])
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def param_shardings(mesh: Mesh, rules: ShardingRules, specs_tree,
                    abstract_tree):
    """Logical-spec tree + abstract-shape tree -> NamedSharding tree."""
    def one(spec, abstract):
        return NamedSharding(
            mesh, spec_for(mesh, rules, spec, abstract.shape))

    return jax.tree.map(
        one, specs_tree, abstract_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )


def make_act_resolver(mesh: Mesh, rules: ShardingRules):
    """Resolver for distributed/api.activation_sharding."""
    def resolve(x, logical):
        if len(logical) != x.ndim:
            return x
        spec = spec_for(mesh, rules, logical, x.shape)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return resolve


def batch_shardings(mesh: Mesh, rules: ShardingRules, batch_abstract):
    """Input batches: leading dim is batch, everything else replicated.

    Exception: very long sequence dims (> 65536) are sequence-sharded (SP)
    when the batch dim cannot be (global_batch == 1 long-context cells).
    """
    def one(ab):
        shape = ab.shape
        if not shape:
            return NamedSharding(mesh, P())
        logical: list = [None] * len(shape)
        logical[0] = "batch"
        if shape[0] == 1 and len(shape) > 1 and shape[1] > 65536:
            logical[1] = "seq"
        return NamedSharding(mesh, spec_for(mesh, rules, logical, shape))

    return jax.tree.map(one, batch_abstract)


def cache_shardings(mesh: Mesh, rules: ShardingRules, cache_abstract,
                    global_batch: int, max_seq: int):
    """KV/SSM caches: shard the batch dim over data, the head-ish dim over
    model, and — when batch is unshardable (long-context, batch 1) — the
    sequence dim over data (SP).

    Dims are identified by SIZE (cache trees are heterogeneous across
    families): the first dim equal to ``global_batch`` is batch; the first
    later non-seq dim divisible by the model-axis extent is the TP dim.
    """
    model_extent = _axis_size(mesh, rules.get("heads"))

    def one(ab):
        shape = ab.shape
        if len(shape) == 0:
            return NamedSharding(mesh, P())
        logical: list = [None] * len(shape)
        b_dim = None
        for i, d in enumerate(shape):
            if i >= 1 and d == global_batch:
                b_dim = i
                break
        if b_dim is not None:
            logical[b_dim] = "batch"
            for i in range(b_dim + 1, len(shape)):
                if shape[i] == max_seq:
                    if global_batch == 1 and max_seq > 65536:
                        logical[i] = "seq"
                    continue
                if model_extent > 1 and shape[i] % model_extent == 0:
                    logical[i] = "kv"
                    break
        return NamedSharding(mesh, spec_for(mesh, rules, logical, shape))

    return jax.tree.map(one, cache_abstract)
