"""``ShardingProblem`` — train-step sharding layouts as a ``TuningProblem``.

The tuning space is the distribution configuration of one model-zoo entry
on a fixed chip count: mesh factorization (data × model), FSDP on/off
(shard the optimizer state over the data axis), and sequence sharding
on/off (shard activations over the model axis).  These are exactly the
knobs ``distributed/sharding.py``'s ``ShardingRules`` expose to the real
train step — the paper's technique applied to distribution configs.

The portable workload model (``g : TP × I → PC_ops``) derives first-order
per-chip counters WITHOUT jax — closed-form parameter/activation/collective
arithmetic over the ``ArchConfig`` — so the fleet, store and TP→PC model
treat a sharding layout exactly like a kernel tile.  The counters carry
the real physics that make layouts trade off:

* tensor parallelism pays ring-all-reduce ICI volume per layer but divides
  the weight-stream and optimizer-state footprint;
* the MLP shard ``d_ff/m`` pads to 256-lane granularity — high TP degrees
  waste MXU lanes (the warp-efficiency analog), counted as extra effective
  ``MXU_FLOPS`` so the TP→PC model can learn the derate;
* without FSDP the full optimizer state must be resident per model shard —
  oversubscribing a reference HBM shows up as ``SPILL_B`` traffic;
* sequence sharding divides activation residency/traffic/VPU work by the
  model degree at no extra ICI volume;
* the per-layer working set (activation tile + MLP weight shard) decides
  whether the cost model grants DMA/compute double buffering.

``make_evaluator(hw)`` is the measurement substrate: the **analytic**
backend prices a skewed copy of the counters (the model never sees the
skew) plus seeded config-keyed jitter — the same good-but-imperfect
regime ``SyntheticServeBackend`` gives the serve problem.  The
**compiled** backend (opt-in, needs jax) lowers the real train step via
``launch/dryrun.lower_cell`` and prices ``roofline.analyze_compiled``'s
HLO-derived flops/bytes/collective volume; it is never used in CI.
"""
from __future__ import annotations

import math
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core import costmodel
from repro.core import counters as C
from repro.core.hwspec import HardwareSpec
from repro.core.tuning_space import Config, TuningParameter, TuningSpace
from repro.models.config import SHAPES, ArchConfig, ShapeConfig
from repro.tuning.problem import TuningProblem

# Bytes per parameter of resident training state: bf16 param + bf16 grad
# + 2x fp32 Adam moments.
STATE_BYTES_PER_PARAM = 12.0
# Reference HBM capacity the *portable* oversubscription counter is taken
# against (the cost model recomputes hardware-true VMEM spill; HBM capacity
# has no portable analog, so the workload reports pressure against a fixed
# reference — the paper's §3.1 imprecision note applies).
REF_HBM_BYTES = 16e9
# MXU lane granularity the MLP shard pads to (256-wide lanes).
LANE_GRAN = 256
# Activation tokens one grid program processes (working-set tile).
TILE_TOKENS = 2048
BYTES = 2.0  # bf16 activations/params on the wire


def mesh_factorizations(n_devices: int) -> List[str]:
    """All power-of-2 ``"<data>x<model>"`` splits of ``n_devices``."""
    n = int(n_devices)
    if n <= 0 or n & (n - 1):
        raise ValueError(f"n_devices must be a power of 2, got {n_devices}")
    out = []
    m = 1
    while m <= n:
        out.append(f"{n // m}x{m}")
        m *= 2
    return out


def parse_mesh(value: str) -> Tuple[int, int]:
    """``"8x8"`` → ``(data, model)`` extents."""
    d, _, m = str(value).partition("x")
    return int(d), int(m)


def sharding_space(n_devices: int, name: str) -> TuningSpace:
    """MESH × FSDP × SEQ × GA, with the no-op corners constrained away
    (FSDP needs a data axis to shard over; SEQ needs a model axis).
    ``GA`` is the gradient-accumulation microbatch count: it divides live
    activation residency (spill relief) at the price of re-streaming the
    fp32 accumulator shard per extra microbatch and more grid programs."""
    return TuningSpace(
        [TuningParameter("MESH", tuple(mesh_factorizations(n_devices))),
         TuningParameter("FSDP", (0, 1)),
         TuningParameter("SEQ", (0, 1)),
         TuningParameter("GA", (1, 2, 4))],
        constraints=(
            lambda c: not (c["FSDP"] and parse_mesh(c["MESH"])[0] == 1),
            lambda c: not (c["SEQ"] and parse_mesh(c["MESH"])[1] == 1),
        ),
        name=name)


# =============================================================================
# jax-free architecture arithmetic
# =============================================================================
def arch_param_count(cfg: ArchConfig) -> float:
    """Closed-form parameter count of a model-zoo entry (all experts)."""
    q = cfg.n_heads * cfg.eff_head_dim
    kv = cfg.n_kv_heads * cfg.eff_head_dim
    attn = cfg.d_model * q + 2.0 * cfg.d_model * kv + q * cfg.d_model
    d_ff = cfg.moe_d_ff or cfg.d_ff
    if cfg.n_experts > 0:
        mlp = (cfg.n_experts + cfg.n_shared_experts) * 3.0 * cfg.d_model \
            * d_ff + cfg.d_model * cfg.n_experts  # router
    else:
        mlp = 3.0 * cfg.d_model * cfg.d_ff
    norms = 2.0 * cfg.d_model
    embed = cfg.padded_vocab * cfg.d_model \
        * (1.0 if cfg.tie_embeddings else 2.0)
    return cfg.n_layers * (attn + mlp + norms) + embed + cfg.d_model


def arch_active_param_count(cfg: ArchConfig) -> float:
    """Parameters a token actually touches (MoE: ``top_k`` experts)."""
    if cfg.n_experts <= 0:
        return arch_param_count(cfg)
    active = cfg.scaled(n_experts=max(1, cfg.top_k))
    return arch_param_count(active)


# =============================================================================
# The problem
# =============================================================================
class ShardingProblem(TuningProblem):
    """Tune the train-step sharding layout of one model-zoo entry.

    ``backend="analytic"`` (default, jax-free) measures through the
    skewed/jittered analytic model; ``backend="compiled"`` lowers the
    real train step per configuration and prices the roofline analysis
    of its HLO (opt-in: slow, needs jax — never in CI).
    """

    kind = "sharding"

    def __init__(self, arch, shape="train_4k", n_devices: int = 64,
                 backend: str = "analytic", noise: float = 0.01,
                 seed: int = 0):
        if isinstance(arch, str):
            from repro.configs import ARCHS
            if arch not in ARCHS:
                raise KeyError(f"unknown model-zoo entry {arch!r}; "
                               f"available: {sorted(ARCHS)}")
            arch = ARCHS[arch]
        if isinstance(shape, str):
            if shape not in SHAPES:
                raise KeyError(f"unknown shape {shape!r}; available: "
                               f"{sorted(SHAPES)}")
            shape = SHAPES[shape]
        if backend not in ("analytic", "compiled"):
            raise ValueError(f"backend must be 'analytic' or 'compiled', "
                             f"got {backend!r}")
        self.arch: ArchConfig = arch
        self.shape: ShapeConfig = shape
        self.n_devices = int(n_devices)
        self.backend = backend
        self.noise = float(noise)
        self.seed = int(seed)
        self.name = f"{arch.name}/{shape.name}"
        self.bucket = f"{shape.name}-c{self.n_devices}"
        self._space: Optional[TuningSpace] = None

    @classmethod
    def from_name(cls, name: str, **params) -> "ShardingProblem":
        """``"<arch>/<shape>"`` (shape optional, default train_4k)."""
        arch, _, shape = name.partition("/")
        return cls(arch, shape or "train_4k", **params)

    def space(self) -> TuningSpace:
        if self._space is None:
            self._space = sharding_space(
                self.n_devices, name=f"sharding_{self.arch.name}")
        return self._space

    # -- the portable counter model -------------------------------------------
    def workload_fn(self) -> Callable[[Config], Dict[str, float]]:
        a, s = self.arch, self.shape
        chips = float(self.n_devices)
        P = arch_param_count(a)
        Pa = arch_active_param_count(a)
        tokens = float(s.seq_len) * float(s.global_batch)
        d_model, n_layers, seq_len = float(a.d_model), float(a.n_layers), \
            float(s.seq_len)
        d_ff = float(a.moe_d_ff or a.d_ff)

        def wl(cfg: Config) -> Dict[str, float]:
            d, m = parse_mesh(cfg["MESH"])
            fsdp, seq = bool(cfg["FSDP"]), bool(cfg["SEQ"])
            ga = float(cfg.get("GA", 1))
            tok_local = tokens / d
            act_shard = float(m) if seq else 1.0

            # compute: dense param flops + head-sharded attention flops.
            # The MLP shard pads to 256-lane granularity (the
            # warp-efficiency analog): counting the padded lanes as issued
            # MXU work keeps the counter a *learnable* per-config effective
            # quantity instead of a side-channel the TP→PC model never sees.
            f_shard = max(1.0, d_ff / m)
            lane_e = (f_shard / LANE_GRAN) / math.ceil(f_shard / LANE_GRAN)
            mxu = (6.0 * Pa * tokens / chips
                   + 12.0 * tok_local * seq_len * d_model * n_layers / m) \
                / lane_e

            # resident training state per chip; HBM oversubscription against
            # the reference capacity is the portable spill counter
            resident = P * STATE_BYTES_PER_PARAM \
                / (m * (d if fsdp else 1.0))
            # only one microbatch's activations are live at a time
            act_resident = n_layers * tok_local * d_model * BYTES * 4.0 \
                / (act_shard * ga)
            spill = 4.0 * max(0.0, resident + act_resident - REF_HBM_BYTES)

            # HBM traffic: state read/update + activation fwd/bwd traffic
            # + the fp32 accumulator shard re-streamed per extra microbatch
            act_traffic = n_layers * tok_local * d_model * BYTES * 6.0 \
                / act_shard
            acc_traffic = (ga - 1.0) * P * 4.0 / (m * (d if fsdp else 1.0))
            hbm_rd = 2.0 * resident + 0.5 * act_traffic + acc_traffic
            hbm_wr = resident + 0.5 * act_traffic + acc_traffic

            # ICI: per-layer TP ring all-reduces + per-step grad/param sync
            tp_coll = 0.0 if m == 1 else \
                4.0 * n_layers * 2.0 * (m - 1.0) / m \
                * tok_local * d_model * BYTES
            dp_coll = 0.0 if d == 1 else \
                (3.0 if fsdp else 2.0) * (d - 1.0) / d * P * BYTES / m
            vpu = n_layers * tok_local * d_model * 20.0 / act_shard

            # per-program working set: activation tile + MLP weight shard
            ws = TILE_TOKENS * d_model * BYTES * 3.0 / (2.0 if seq else 1.0) \
                + d_model * (d_ff / m) * BYTES
            grid = n_layers * math.ceil(tok_local / ga / TILE_TOKENS) * ga
            return {
                C.MXU_FLOPS: float(mxu),
                C.VPU_OPS: float(vpu),
                C.ISSUE_OPS: float(mxu / 128.0 + vpu),
                C.HBM_RD: float(hbm_rd),
                C.HBM_WR: float(hbm_wr),
                C.VMEM_RD: float(2.0 * hbm_rd),
                C.VMEM_WR: float(2.0 * hbm_wr),
                C.SPILL_B: float(spill),
                C.ICI_B: float(tp_coll + dp_coll),
                C.VMEM_WS: float(ws),
                C.GRID: float(grid),
            }

        return wl

    # -- measurement substrates -----------------------------------------------
    def measured_runtime(self, cfg: Config, hw: HardwareSpec) -> float:
        """Deterministic 'ground truth' step time of one layout: the
        analytic model over hardware-skewed counters plus seeded jitter
        (the oracle ``bench_systems`` ranks predictions against)."""
        cs = self._measure(cfg, hw)
        return cs.runtime

    def _measure(self, cfg: Config, hw: HardwareSpec):
        wl = self.workload_fn()
        ops = wl(cfg)
        ops[C.HBM_RD] = ops[C.HBM_RD] * 1.12      # the model never sees
        ops[C.ICI_B] = ops[C.ICI_B] * 1.15        # these skews
        cs = costmodel.execute(ops, hw)
        d, m = parse_mesh(cfg["MESH"])
        rng = np.random.default_rng(
            [self.seed, d, m, int(cfg["FSDP"]), int(cfg["SEQ"]),
             int(cfg.get("GA", 1))])
        jitter = (2.0 * rng.random() - 1.0) * self.noise
        cs.runtime = cs.runtime * (1.0 + jitter) + 2e-3
        return cs

    def make_evaluator(self, hw: HardwareSpec) -> Optional[Callable]:
        if self.backend == "compiled":
            return self._compiled_evaluator(hw)
        from repro.core.evaluate import (PROFILE_FIXED, PROFILE_SLOWDOWN,
                                         TEST_OVERHEAD)
        space = self.space()

        def fn(index: int, profile: bool):
            cs = self._measure(space[int(index)], hw)
            rt = float(cs.runtime)
            if profile:
                return rt, cs, rt * PROFILE_SLOWDOWN + TEST_OVERHEAD \
                    + PROFILE_FIXED
            return rt, None, rt + TEST_OVERHEAD

        return fn

    def _compiled_evaluator(self, hw: HardwareSpec) -> Callable:
        """Lower the REAL train step per configuration; price the
        HLO-derived roofline (flops / HBM bytes / ring-scaled collective
        bytes) as counters.  The production mesh fixes the chip layout,
        so only the rules knobs (FSDP/SEQ/TP) vary here — mesh-shape
        pricing stays with the analytic backend."""
        from repro.core.evaluate import (PROFILE_FIXED, PROFILE_SLOWDOWN,
                                         TEST_OVERHEAD)
        space = self.space()

        def fn(index: int, profile: bool):
            from repro.launch.dryrun import lower_cell
            cfg = space[int(index)]
            _, m = parse_mesh(cfg["MESH"])
            rec = lower_cell(
                self.arch.name, self.shape.name, multi_pod=False,
                step_overrides={"microbatches": int(cfg.get("GA", 1))},
                rules_overrides={
                    "embed": "data" if cfg["FSDP"] else None,
                    "seq": "data" if cfg["SEQ"] else None,
                    **({} if m > 1 else
                       {k: None for k in
                        ("vocab", "heads", "kv", "mlp", "expert")}),
                },
                verbose=False)
            rf = rec["roofline"]
            rt = max(float(rf["compute_s"]), float(rf["memory_s"]),
                     float(rf["collective_s"]))
            chips = max(1.0, float(rf.get("chips", 1)))
            ops = {
                C.MXU_FLOPS: float(rf["flops"]) / chips,
                C.HBM_RD: 0.6 * float(rf["hbm_bytes"]) / chips,
                C.HBM_WR: 0.4 * float(rf["hbm_bytes"]) / chips,
                C.ICI_B: float(rf["collective_bytes"]),
                C.GRID: float(self.arch.n_layers),
            }
            cs = costmodel.execute(ops, hw)
            cs.runtime = max(rt, 1e-9)
            if profile:
                return cs.runtime, cs, rt * PROFILE_SLOWDOWN \
                    + TEST_OVERHEAD + PROFILE_FIXED
            return cs.runtime, None, rt + TEST_OVERHEAD

        return fn
