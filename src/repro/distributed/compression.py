"""Gradient compression for the slow cross-pod (DCN) link.

Two mechanisms:

* ``quantize_dequantize_tree`` — int8 symmetric quantization with error
  feedback applied inside the jitted step.  Under GSPMD the gradient
  all-reduce happens during backward, so this variant models compression
  numerics (and is what the numerics tests cover) while keeping the step a
  single GSPMD program.

* ``cross_pod_int8_psum`` — the real traffic reducer: an explicit int8
  all-reduce over the manual "pod" mesh axis inside ``shard_map`` (data and
  model axes stay auto/GSPMD).  Shared-scale symmetric quantization: one
  f32 pmax for the scale, one int32 psum of int8 payloads — 4x less DCN
  traffic than an f32 all-reduce.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp


def _quantize(g: jax.Array, scale: jax.Array) -> jax.Array:
    q = jnp.clip(jnp.round(g / jnp.maximum(scale, 1e-20) * 127.0),
                 -127, 127)
    return q.astype(jnp.int8)


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale / 127.0


def quantize_dequantize_tree(grads: Any) -> Any:
    """Per-leaf int8 round-trip (compression numerics inside one program)."""
    def one(g):
        gf = g.astype(jnp.float32)
        scale = jnp.max(jnp.abs(gf))
        return _dequantize(_quantize(gf, scale), scale).astype(g.dtype)

    return jax.tree.map(one, grads)


def cross_pod_int8_psum(grads: Any, axis_name: str = "pod") -> Any:
    """int8 all-reduce over a manual mesh axis (call inside shard_map)."""
    def one(g):
        gf = g.astype(jnp.float32)
        scale = jax.lax.pmax(jnp.max(jnp.abs(gf)), axis_name)
        q = _quantize(gf, scale)
        s = jax.lax.psum(q.astype(jnp.int32), axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.int32), axis_name)
        return (_dequantize(s, scale) / n.astype(jnp.float32)).astype(g.dtype)

    return jax.tree.map(one, grads)
