"""``repro.tuning`` — the public ask-tell autotuning API.

The coherent surface over the paper's two-phase method:

* ``TuningSession`` — explicit ``train()`` / ``tune()`` phases, portable
  model artifacts (``save_model``/``load_model``).
* ``SEARCHERS`` — string-keyed registry of ask-tell searchers, all
  constructible as ``SEARCHERS[name](space, seed=s, ...)``; ``run_search``
  is the uniform driver loop.
* ``Evaluator`` protocol + ``EvalAccount`` — shared
  measure/profile/measure_many accounting implemented by every evaluator
  (replay, cost model, real compiles, timed callables).
* ``model_to_dict``/``model_from_dict`` — JSON round-trip for trained
  TP→PC_ops models (the portability artifact).
* ``ConfigStore`` — persistent JSON store of tuned configs + model artifacts
  keyed by (space name, input-shape bucket, hardware); the substrate for
  the online serving tuner's zero-trial reuse.

Quickstart::

    from repro.core import SPECS
    from repro.kernels.registry import BENCHMARKS
    from repro.tuning import TuningSession

    bm = BENCHMARKS["matmul"]
    session = TuningSession(bm.make_space(),
                            lambda c: bm.workload_fn(c, bm.default_input),
                            hw=SPECS["tpu_v5e"])
    session.train(train_hw=SPECS["tpu_v4"])   # model from DIFFERENT hardware
    result = session.tune(budget=25)
"""
from repro.core.account import (Candidate, EvalAccount, Evaluator,
                                Observation, ProfilingUnsupported, Ticket)
from repro.core.evaluate import (CostModelEvaluator, FunctionEvaluator,
                                 RecordedSpace, ReplayEvaluator,
                                 VirtualAsyncEvaluator, record_space)
from repro.core.searcher import (SEARCHERS, Searcher, make_searcher,
                                 register_searcher, resolve_searcher,
                                 run_search, sequential_run_search)
from repro.core.tuner import TuneResult, train_model, train_model_deliberate
from repro.tuning.serialize import (artifact_signature, ensure_signature,
                                    model_from_dict, model_to_dict,
                                    rebind_model_dict, space_from_dict,
                                    space_to_dict)
from repro.tuning.signature import (DEFAULT_TRANSFER_THRESHOLD, ParamSlot,
                                    SpaceSignature, map_parameters,
                                    similarity, transfer_compatible)
from repro.tuning.problem import (KernelProblem, TuningProblem, list_problems,
                                  make_problem, parse_problem, problem_kinds,
                                  register_problem_kind)
from repro.tuning.session import TuningSession
from repro.tuning.store import (ConfigStore, StoreEntry, legacy_kind,
                                split_key, store_key, upgrade_key)

__all__ = [
    "Candidate", "ConfigStore", "CostModelEvaluator",
    "DEFAULT_TRANSFER_THRESHOLD", "EvalAccount",
    "Evaluator", "FunctionEvaluator", "KernelProblem", "Observation",
    "ParamSlot", "ProfilingUnsupported", "RecordedSpace", "ReplayEvaluator",
    "SEARCHERS", "Searcher", "SpaceSignature", "StoreEntry", "Ticket",
    "TuneResult", "TuningProblem", "TuningSession", "VirtualAsyncEvaluator",
    "artifact_signature", "ensure_signature", "legacy_kind", "list_problems",
    "make_problem", "make_searcher", "map_parameters", "model_from_dict",
    "model_to_dict", "parse_problem", "problem_kinds", "rebind_model_dict",
    "record_space", "register_problem_kind", "register_searcher",
    "resolve_searcher", "run_search", "sequential_run_search", "similarity",
    "split_key", "space_from_dict", "space_to_dict", "store_key",
    "train_model", "train_model_deliberate", "transfer_compatible",
    "upgrade_key",
]
