"""Structural space signatures — the key for cross-space model transfer.

The paper's portability claim (§4.4/§4.5) is that TP→PC models carry
across GPUs and inputs because performance counters, not runtimes, are
the learned target.  The sister data paper (arXiv 2102.05299) goes one
step further: counter features are shared across *kernels*, so a model
trained on one tuning space is a useful prior for a structurally similar
space it has never seen.  This module gives that notion of "structurally
similar" a concrete, serializable form:

* ``SpaceSignature`` — the problem kind, the space name, one hashed
  ``ParamSlot`` per tuning parameter (name hash + value-structure hash +
  the encoded value codes), and the set of counter names the space's
  workload emits.  Computable from parameter lists alone (no config
  enumeration), from a ``TuningSpace``, or from a ``TuningProblem``.
* ``similarity(sig_a, sig_b)`` — counter-set Jaccard × parameter-
  structure overlap, in [0, 1].
* ``transfer_compatible(sig_a, sig_b)`` — the gate the store's
  compatible-space tier applies: same problem kind, shared counters,
  similarity at or above a conservative threshold.

Parameter matching is the hashed-slot idiom (archai's ``transfer_utils``
applies it to hashed layer names when grafting weights between network
variants): each parameter hashes both its *name* and its *value
structure*, so a renamed parameter still pairs by structure hash, an
extended parameter (same name, more values) still pairs by name hash,
and the pair's score is the Jaccard of the encoded value sets — partial
credit for partial range overlap.  ``match_slots`` returns the pairing
itself, which is what model rebinding uses to route a target config's
values into the source model's feature columns.

Deliberately import-light (``repro.core.tuning_space`` only): the store,
the serializer and the fleet all build on it without cycles.
"""
from __future__ import annotations

import dataclasses
import json
import zlib
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.tuning_space import TuningParameter, TuningSpace

SIG_FORMAT = "repro.space_signature"
SIG_VERSION = 1

# Conservative default gate for the store's compatible-space tier: high
# enough that a sharded-layout or serve-geometry space does not
# masquerade as a kernel-tile prior on range overlap alone, low enough
# that sibling kernel spaces (shared counter sets, block-size-shaped
# parameters) pass.  Operators pin it per deployment via
# ``--transfer-threshold`` / ``FleetTuner(transfer_threshold=...)``.
DEFAULT_TRANSFER_THRESHOLD = 0.35


def _crc_hex(obj: Any) -> str:
    """Stable 8-hex-digit content hash of a JSON-safe object."""
    blob = json.dumps(obj, separators=(",", ":"), sort_keys=True)
    return f"{zlib.crc32(blob.encode('utf-8')):08x}"


def _param_codes(p: TuningParameter) -> Tuple[float, ...]:
    """Sorted unique feature codes of a parameter's declared values —
    the numeric shadow every model consumes (``TuningParameter.encode``),
    so two parameters with the same codes are interchangeable slots."""
    return tuple(sorted({float(p.encode(v)) for v in p.values}))


@dataclasses.dataclass(frozen=True)
class ParamSlot:
    """One tuning parameter's hashed structural identity.

    ``name_hash`` pairs renamed-compatible slots (same name, possibly
    extended values); ``struct_hash`` pairs renamed slots (same value
    structure under a different name); ``codes`` carries the encoded
    value set so a pair's score — and cross-space value snapping — can
    be computed without the original parameter object.
    """

    name_hash: str
    struct_hash: str
    is_binary: bool
    codes: Tuple[float, ...]

    @staticmethod
    def of(p: TuningParameter) -> "ParamSlot":
        codes = _param_codes(p)
        return ParamSlot(
            name_hash=_crc_hex(p.name),
            struct_hash=_crc_hex([bool(p.is_binary), list(codes)]),
            is_binary=bool(p.is_binary),
            codes=codes,
        )

    def to_dict(self) -> Dict[str, Any]:
        return {"name_hash": self.name_hash,
                "struct_hash": self.struct_hash,
                "is_binary": self.is_binary,
                "codes": list(self.codes)}

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "ParamSlot":
        return ParamSlot(
            name_hash=str(d["name_hash"]),
            struct_hash=str(d["struct_hash"]),
            is_binary=bool(d["is_binary"]),
            codes=tuple(float(c) for c in d["codes"]),
        )


def _code_jaccard(a: ParamSlot, b: ParamSlot) -> float:
    """Value-set overlap of two slots: Jaccard over encoded codes, so an
    extended parameter scores the shared prefix rather than 0 or 1."""
    sa, sb = set(a.codes), set(b.codes)
    union = sa | sb
    if not union:
        return 1.0
    return len(sa & sb) / len(union)


def match_slots(a: Sequence[ParamSlot], b: Sequence[ParamSlot]
                ) -> List[Tuple[int, int, float]]:
    """Pair slots of two signatures: ``(index_in_a, index_in_b, score)``.

    Three passes, each consuming only still-unpaired slots, all ties
    broken in declared order (deterministic across processes):

    1. **name hash** — the common case (same parameter, possibly with an
       extended value list);
    2. **structure hash** — a renamed parameter with an identical value
       structure;
    3. **greedy value overlap** — renamed AND reshaped parameters pair
       by best code-set Jaccard, binary slots only with binary slots.

    The pair score is the code-set Jaccard in every pass.
    """
    pairs: List[Tuple[int, int, float]] = []
    used_a: set = set()
    used_b: set = set()
    by_name: Dict[str, int] = {}
    for j, sb in enumerate(b):
        by_name.setdefault(sb.name_hash, j)
    for i, sa in enumerate(a):
        j = by_name.get(sa.name_hash)
        if j is not None and j not in used_b:
            pairs.append((i, j, _code_jaccard(sa, b[j])))
            used_a.add(i)
            used_b.add(j)
    for i, sa in enumerate(a):
        if i in used_a:
            continue
        for j, sb in enumerate(b):
            if j in used_b or sb.struct_hash != sa.struct_hash:
                continue
            pairs.append((i, j, _code_jaccard(sa, sb)))
            used_a.add(i)
            used_b.add(j)
            break
    ranked: List[Tuple[float, int, int]] = []
    for i, sa in enumerate(a):
        if i in used_a:
            continue
        for j, sb in enumerate(b):
            if j in used_b or sb.is_binary != sa.is_binary:
                continue
            s = _code_jaccard(sa, sb)
            if s > 0.0:
                ranked.append((-s, i, j))
    for neg_s, i, j in sorted(ranked):
        if i in used_a or j in used_b:
            continue
        pairs.append((i, j, -neg_s))
        used_a.add(i)
        used_b.add(j)
    return pairs


@dataclasses.dataclass(frozen=True)
class SpaceSignature:
    """Structural identity of one tuning problem's space.

    ``kind`` is the ``TuningProblem`` registry string ("kernel",
    "serve", ...) — transfer NEVER crosses kinds; ``space`` the space
    name (informational: the store's compatible-space tier only consults
    it to exclude same-space artifacts the legacy tiers already cover);
    ``slots`` one ``ParamSlot`` per parameter in declared order;
    ``counters`` the sorted counter-name set the space's workload emits
    (for a stored model artifact: the counters the model predicts).
    """

    kind: str
    space: str
    slots: Tuple[ParamSlot, ...]
    counters: Tuple[str, ...]

    # -- constructors ----------------------------------------------------------
    @staticmethod
    def from_parameters(parameters: Sequence[TuningParameter],
                        kind: str, space: str,
                        counters: Sequence[str] = ()) -> "SpaceSignature":
        """The core constructor: parameter (name, values) lists are all
        the structure needed — no config enumeration, so signing a
        200k-config space (or a serialized artifact's recorded
        parameters) costs O(params)."""
        return SpaceSignature(
            kind=str(kind), space=str(space),
            slots=tuple(ParamSlot.of(p) for p in parameters),
            counters=tuple(sorted(set(str(c) for c in counters))),
        )

    @staticmethod
    def from_space(space: TuningSpace, kind: str,
                   counters: Sequence[str] = ()) -> "SpaceSignature":
        return SpaceSignature.from_parameters(
            space.parameters, kind=kind, space=space.name,
            counters=counters)

    @staticmethod
    def from_problem(problem) -> "SpaceSignature":
        """Sign any ``TuningProblem``: counter names are sampled from one
        workload evaluation (the portable ``g(TP) → PC`` model is pure
        and cheap — no hardware touched)."""
        space = problem.space()
        counters: Sequence[str] = ()
        try:
            counters = sorted(problem.workload_fn()(space[0]))
        except Exception:
            pass   # a problem without a workable counter model still signs
        return SpaceSignature.from_space(space, kind=problem.kind,
                                         counters=counters)

    # -- identity / persistence -------------------------------------------------
    @property
    def sig_hash(self) -> str:
        """Content hash of the whole signature (stats/log identity)."""
        return _crc_hex(self.to_dict())

    def to_dict(self) -> Dict[str, Any]:
        return {
            "format": SIG_FORMAT,
            "version": SIG_VERSION,
            "kind": self.kind,
            "space": self.space,
            "slots": [s.to_dict() for s in self.slots],
            "counters": list(self.counters),
        }

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "SpaceSignature":
        if d.get("format") != SIG_FORMAT:
            raise ValueError(
                f"not a {SIG_FORMAT} dict: format={d.get('format')!r}")
        if d.get("version") != SIG_VERSION:
            raise ValueError(
                f"unsupported {SIG_FORMAT} version {d.get('version')!r}")
        return SpaceSignature(
            kind=str(d.get("kind", "")),
            space=str(d.get("space", "")),
            slots=tuple(ParamSlot.from_dict(s) for s in d.get("slots", [])),
            counters=tuple(str(c) for c in d.get("counters", [])),
        )


def counter_jaccard(sig_a: SpaceSignature, sig_b: SpaceSignature) -> float:
    """Jaccard over the counter-name sets (1.0 when both are empty —
    two spaces that name no counters are vacuously counter-compatible)."""
    ca, cb = set(sig_a.counters), set(sig_b.counters)
    union = ca | cb
    if not union:
        return 1.0
    return len(ca & cb) / len(union)


def parameter_overlap(sig_a: SpaceSignature, sig_b: SpaceSignature) -> float:
    """Matched-slot score mass over the larger parameter count, in
    [0, 1]: 1.0 only when every parameter of the larger space pairs with
    an identical-valued slot of the other."""
    na, nb = len(sig_a.slots), len(sig_b.slots)
    if na == 0 and nb == 0:
        return 1.0
    if na == 0 or nb == 0:
        return 0.0
    pairs = match_slots(sig_a.slots, sig_b.slots)
    return sum(s for _, _, s in pairs) / max(na, nb)


def similarity(sig_a: SpaceSignature, sig_b: SpaceSignature) -> float:
    """Counter-set Jaccard × parameter-structure overlap — the transfer
    metric the store's compatible-space tier ranks candidates by."""
    return counter_jaccard(sig_a, sig_b) * parameter_overlap(sig_a, sig_b)


def transfer_compatible(sig_a: SpaceSignature, sig_b: SpaceSignature,
                        threshold: float = DEFAULT_TRANSFER_THRESHOLD
                        ) -> bool:
    """Whether a model signed ``sig_a`` may warm-start a job signed
    ``sig_b`` (symmetric): SAME problem kind — a serve-geometry model
    must never prior a kernel job however similar the ranges look — at
    least one shared counter to predict through (unless neither side
    names any), and similarity at or above the threshold."""
    if sig_a.kind != sig_b.kind:
        return False
    if (sig_a.counters or sig_b.counters) \
            and not (set(sig_a.counters) & set(sig_b.counters)):
        return False
    return similarity(sig_a, sig_b) >= float(threshold)


def map_parameters(source: SpaceSignature, target: SpaceSignature
                   ) -> Dict[int, int]:
    """Source-slot index → target-slot index for model rebinding: the
    hashed-slot pairing of ``match_slots``, zero-score pairs dropped
    (nothing sensible to route through a fully disjoint value set)."""
    return {i: j for i, j, s in match_slots(source.slots, target.slots)
            if s > 0.0}
