"""``TuningSession`` — the paper's two-phase workflow as one object.

Phase 1 (``train``): build a portable TP→PC_ops model from a tuning space
recorded on ANY hardware/input (the ``train_hw`` argument makes the
cross-hardware scenario explicit).  The trained model is an artifact:
``save_model``/``load_model`` round-trip it through JSON so a model trained
on one (virtual) GPU ships to another machine.

Phase 2 (``tune``): counter-guided (or baseline) search on the
hardware/input of interest, through any evaluator implementing the shared
protocol, driven in ask-tell form.

    session = TuningSession(space, workload_fn, hw=SPECS["tpu_v5e"], seed=0)
    session.train(train_hw=SPECS["tpu_v4"])        # or load_model(path)
    result = session.tune(budget=25)               # ProfileBasedSearcher
    session.save_model("gemm_tppc.json")           # ship it elsewhere
"""
from __future__ import annotations

import json
from typing import Callable, Dict, Optional, Sequence, Union

import numpy as np

from repro.core.account import Evaluator
from repro.core.evaluate import CostModelEvaluator, record_space
from repro.core.hwspec import HardwareSpec
from repro.core.model import DecisionTreeModel, TPPCModel, \
    deliberate_training_sample
from repro.core.searcher import Searcher, make_searcher, run_search
from repro.core.tuner import (TuneResult, train_model, train_model_deliberate)
from repro.core.tuning_space import Config, TuningSpace
from repro.tuning.serialize import model_from_dict, model_to_dict


class TuningSession:
    """Explicit train/tune phases over one tuning space.

    Parameters
    ----------
    space : the tuning space (what to search).
    workload_fn : portable workload model ``g(TP) -> PC_ops`` — needed for
        the cost-model evaluator and for ``train()``; optional when a custom
        evaluator and a pre-trained/loaded model are supplied instead.
    hw : the hardware OF INTEREST (autotuning target).  Optional when every
        ``tune()`` call passes its own evaluator.
    model : a pre-trained TP→PC_ops model (skips the training phase).
    seed : default RNG seed for training sampling and searchers.
    """

    def __init__(
        self,
        space: TuningSpace,
        workload_fn: Optional[Callable[[Config], Dict[str, float]]] = None,
        hw: Optional[HardwareSpec] = None,
        *,
        model: Optional[TPPCModel] = None,
        seed: int = 0,
    ):
        self.space = space
        self.workload_fn = workload_fn
        self.hw = hw
        self.model = model
        self.seed = seed
        self.train_record = None
        self.result: Optional[TuneResult] = None

    # =========================================================================
    # Phase 1 — training (anywhere)
    # =========================================================================
    def train(
        self,
        train_hw: Optional[HardwareSpec] = None,
        kind: str = "tree",
        sample: Union[str, Sequence[int]] = "deliberate",
        seed: Optional[int] = None,
    ) -> TPPCModel:
        """Record the space on ``train_hw`` (default: the target hardware)
        and fit a TP→PC_ops model.

        ``sample``: 'deliberate' (§3.4.1 2-3-values-per-parameter), 'full'
        (exhaustive), or an explicit sequence of config indices.
        """
        if self.workload_fn is None:
            raise ValueError("train() needs workload_fn; use "
                             "train_on_evaluator() or load_model() instead")
        hw = train_hw if train_hw is not None else self.hw
        if hw is None:
            raise ValueError("train() needs train_hw or a session hw")
        seed = self.seed if seed is None else seed
        rec = record_space(self.space, self.workload_fn, hw)
        if isinstance(sample, str):
            if sample == "deliberate":
                self.model = train_model_deliberate(rec, kind=kind, seed=seed)
            elif sample == "full":
                self.model = train_model(rec, kind=kind, seed=seed)
            else:
                raise ValueError(f"unknown sample strategy {sample!r}")
        else:
            self.model = train_model(rec, kind=kind, sample=sample, seed=seed)
        self.train_record = rec
        return self.model

    def train_on_evaluator(
        self,
        evaluator: Evaluator,
        sample: Optional[Sequence[int]] = None,
        values_per_param: int = 2,
        max_samples: Optional[int] = None,
        seed: Optional[int] = None,
    ) -> TPPCModel:
        """Training phase against a live evaluator (e.g. real compiles):
        profile a deliberate sample of its space and fit a decision tree.

        The profiled tests are charged to ``evaluator``'s account — in the
        expensive-measurement regime they are real empirical tests.
        """
        seed = self.seed if seed is None else seed
        idxs = list(sample) if sample is not None else \
            deliberate_training_sample(
                evaluator.space, values_per_param=values_per_param,
                rng=np.random.default_rng(seed))
        if max_samples is not None:
            idxs = idxs[:max_samples]
        cfgs, counters = [], []
        for i in idxs:
            cs = evaluator.profile(i)
            cfgs.append(evaluator.space[i])
            counters.append(cs.ops)
        self.model = DecisionTreeModel(evaluator.space, cfgs, counters,
                                       rng=np.random.default_rng(seed))
        return self.model

    # =========================================================================
    # The artifact — portable models
    # =========================================================================
    def save_model(self, path: str) -> str:
        """Write the trained model (+ space parameters) to JSON."""
        if self.model is None:
            raise ValueError("no trained model to save; call train() first")
        with open(path, "w") as f:
            json.dump(model_to_dict(self.model, self.space), f)
        return path

    def load_model(self, path: str) -> TPPCModel:
        """Load a model artifact, binding it to this session's space."""
        with open(path) as f:
            self.model = model_from_dict(json.load(f), space=self.space)
        return self.model

    def save_model_to_store(self, store, bucket: str,
                            hardware: Optional[str] = None,
                            kind: Optional[str] = None) -> None:
        """Publish the trained model into a ``ConfigStore`` under
        ``(kind, space name, bucket, hardware)`` — the persistent analog
        of ``save_model`` for online/serving tuners.  ``hardware``
        defaults to the session's target hardware name; ``kind`` is the
        problem-kind namespace (default: inferred from the space name)."""
        if self.model is None:
            raise ValueError("no trained model to save; call train() first")
        hw = hardware if hardware is not None else (
            self.hw.name if self.hw is not None else "any")
        store.save_model(self.space.name, bucket, hw, self.model, self.space,
                         kind=kind)

    def load_model_from_store(self, store, bucket: str,
                              hardware: Optional[str] = None,
                              kind: Optional[str] = None
                              ) -> Optional[TPPCModel]:
        """Bind a stored model artifact to this session (None on miss)."""
        hw = hardware if hardware is not None else (
            self.hw.name if self.hw is not None else "any")
        model = store.load_model(self.space.name, bucket, hw,
                                 bind_space=self.space, kind=kind)
        if model is not None:
            self.model = model
        return model

    def prediction_matrix(self):
        """(counter_names, n_configs × n_counters) predictions of the
        session's model over its space — the array the profile searchers
        score against, shared/memoized per (model, space).  Useful for
        inspecting what the portable model believes about the space without
        running a search."""
        if self.model is None:
            raise ValueError("no model; call train() or load_model() first")
        from repro.core.model import prediction_matrix

        return prediction_matrix(self.model, self.space)

    # =========================================================================
    # Phase 2 — autotuning (on the hardware/input of interest)
    # =========================================================================
    def make_evaluator(self) -> Evaluator:
        """Default evaluator: the workload model on the target hardware."""
        if self.workload_fn is None or self.hw is None:
            raise ValueError(
                "session has no workload_fn/hw; pass evaluator= to tune()")
        return CostModelEvaluator(self.space, self.workload_fn, self.hw)

    def make_searcher(self, searcher: Union[str, type, Searcher] = "profile",
                      seed: Optional[int] = None, **kwargs) -> Searcher:
        """Instantiate a searcher bound to this session's model/hardware.

        The session's model and core count are passed implicitly (cores
        falls back to 1 when the session has no hw — e.g. the step tuner's
        single-core roofline).  Explicit ``kwargs`` are validated against
        the searcher's constructor so typos raise instead of vanishing.
        """
        if isinstance(searcher, Searcher):
            if kwargs or seed is not None:
                raise TypeError(
                    "searcher options/seed cannot be applied to an "
                    "already-constructed searcher instance")
            return searcher
        import inspect

        from repro.core.searcher import resolve_searcher

        cls = resolve_searcher(searcher)
        params = inspect.signature(cls.__init__).parameters
        unknown = sorted(k for k in kwargs if k not in params)
        if unknown:
            options = sorted(set(params) - {"self", "space", "seed"})
            raise TypeError(
                f"{cls.__name__} does not accept {unknown}; "
                f"its options are {options}")
        context = dict(model=self.model,
                       cores=self.hw.cores if self.hw is not None else 1)
        context.update(kwargs)
        return make_searcher(cls, self.space,
                             seed=self.seed if seed is None else seed,
                             **context)

    def tune(
        self,
        budget: int = 60,
        searcher: Union[str, type, Searcher] = "profile",
        evaluator: Optional[Evaluator] = None,
        seed: Optional[int] = None,
        in_flight: int = 1,
        **searcher_kwargs,
    ) -> TuneResult:
        """Run the autotuning phase: ask-tell search under a step budget.

        ``in_flight`` > 1 keeps that many empirical tests outstanding on the
        evaluator (meaningful with async backends — the default synchronous
        shim still evaluates serially, and ``in_flight=1`` replays the
        sequential driver exactly).
        """
        ev = evaluator if evaluator is not None else self.make_evaluator()
        s = self.make_searcher(searcher, seed=seed, **searcher_kwargs)
        run_search(s, ev, budget, in_flight=in_flight)
        if ev.best_index is None:
            raise RuntimeError("search made no empirical tests "
                               "(budget <= 0 or empty space?)")
        per_config: Dict[int, float] = {}
        for idx, rt in ev.history():
            per_config.setdefault(idx, rt)
        self.result = TuneResult(
            best_config=ev.space[ev.best_index],
            best_runtime=ev.best_runtime,
            steps=ev.steps,
            history=sorted(per_config.items()),
        )
        return self.result
