"""JSON round-trip for trained TP→PC_ops models — the portability artifact.

The paper's headline claim is that a model trained on one GPU/input steers
autotuning on another.  ``model_to_dict``/``model_from_dict`` turn that
claim into a shippable file: train anywhere, ``TuningSession.save_model``,
copy the JSON to the machine of interest, ``load_model`` and tune.

Serialized alongside the model are the tuning-space *parameters* (names and
value lists) — everything the models need to vectorize configurations.
Space constraints are predicates and are NOT serialized; tree/quadratic
models never consult space indexing, and exact models carry their own
explicit (config, counters) pairs, so reconstruction is faithful either way.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.core.model import (DecisionTreeModel, ExactCounterModel,
                              QuadraticRegressionModel, TPPCModel,
                              TransferredModel, _Node)
from repro.core.tuning_space import TuningParameter, TuningSpace
from repro.tuning.signature import SpaceSignature, map_parameters

FORMAT = "repro.tppc_model"
VERSION = 1


# -- tuning space (parameters only) -------------------------------------------
def space_to_dict(space: TuningSpace) -> Dict:
    return {
        "name": space.name,
        "parameters": [
            {"name": p.name, "values": list(p.values)}
            for p in space.parameters
        ],
    }


def space_from_dict(d: Dict) -> TuningSpace:
    return TuningSpace(
        [TuningParameter(p["name"], tuple(p["values"]))
         for p in d["parameters"]],
        name=d.get("name", "space"),
    )


# -- decision trees ------------------------------------------------------------
def _node_to_dict(n: _Node) -> Dict:
    if n.is_leaf:
        return {"value": n.value}
    return {
        "value": n.value,
        "feature": n.feature,
        "threshold": n.threshold,
        "left": _node_to_dict(n.left),
        "right": _node_to_dict(n.right),
    }


def _node_from_dict(d: Dict) -> _Node:
    node = _Node(value=float(d["value"]))
    if "feature" in d:
        node.feature = int(d["feature"])
        node.threshold = float(d["threshold"])
        node.left = _node_from_dict(d["left"])
        node.right = _node_from_dict(d["right"])
    return node


def _check_space_compatible(space: TuningSpace, space_dict: Dict) -> None:
    """Models vectorize configs by the bound space's parameter order and
    value lists — a mismatch would silently mispredict, so refuse it."""
    ours = [(p.name, list(p.values)) for p in space.parameters]
    theirs = [(p["name"], list(p["values"])) for p in space_dict["parameters"]]
    if ours != theirs:
        raise ValueError(
            "model artifact was trained on an incompatible tuning space: "
            f"artifact parameters {theirs} vs target space {ours}")


# -- structural signatures on artifacts ----------------------------------------
def artifact_counter_names(d: Dict) -> List[str]:
    """The counter names a serialized model predicts, by artifact kind —
    the counter half of an artifact's signature, recoverable from any
    legacy (signature-less) artifact."""
    kind = d.get("kind")
    if kind == "tree":
        return sorted(d.get("trees", {}))
    if kind == "quadratic":
        return sorted(d.get("counter_names", []))
    if kind == "exact":
        names: set = set()
        for rec in d.get("counters", []):
            names.update(rec)
        return sorted(names)
    return []


def artifact_signature(d: Dict, kind: Optional[str] = None
                       ) -> Optional[SpaceSignature]:
    """The structural signature of a serialized model artifact.

    Reads the embedded ``signature`` dict when the artifact carries one;
    otherwise recomputes it from the recorded space parameters and the
    model's counter names (the v2→v3 store upgrade path for legacy
    artifacts).  ``kind`` overrides/supplies the problem kind — pass the
    store key's kind so legacy artifacts sign under the right registry
    string.  Returns None when the artifact has no recoverable structure.
    """
    sig_d = d.get("signature")
    if isinstance(sig_d, dict):
        try:
            sig = SpaceSignature.from_dict(sig_d)
            if kind is not None and sig.kind != kind:
                sig = SpaceSignature(kind=str(kind), space=sig.space,
                                     slots=sig.slots, counters=sig.counters)
            return sig
        except (ValueError, KeyError, TypeError):
            pass
    space_d = d.get("space")
    if not isinstance(space_d, dict) or "parameters" not in space_d:
        return None
    try:
        space = space_from_dict(space_d)
    except (KeyError, TypeError, ValueError):
        return None
    return SpaceSignature.from_space(
        space, kind=str(kind) if kind is not None else "kernel",
        counters=artifact_counter_names(d))


def ensure_signature(d: Dict, kind: Optional[str] = None) -> Dict:
    """Return ``d`` with an embedded ``signature`` dict, computing one for
    legacy artifacts.  Tolerant: an artifact whose structure cannot be
    signed is returned unchanged (it simply never matches a transfer
    tier)."""
    if isinstance(d.get("signature"), dict):
        return d
    sig = artifact_signature(d, kind=kind)
    if sig is None:
        return d
    out = dict(d)
    out["signature"] = sig.to_dict()
    return out


def rebind_model_dict(d: Dict, target_space: TuningSpace,
                      target_signature: SpaceSignature,
                      source_key: Optional[str] = None,
                      similarity: float = 0.0) -> TransferredModel:
    """Load a serialized model and rebind it onto a *different* space: the
    cross-space transfer read path.  Parameters map via hashed slots
    (``map_parameters``), predictions flow through the shared-counter
    intersection."""
    source = model_from_dict(d)     # bound to its own recorded space
    sig = artifact_signature(d, kind=target_signature.kind)
    if sig is None:
        raise ValueError("artifact has no recoverable space signature; "
                         "cannot rebind it onto another space")
    return TransferredModel(
        source, target_space,
        param_map=map_parameters(sig, target_signature),
        counters=target_signature.counters or None,
        similarity=similarity, source_key=source_key)


# -- model <-> dict ------------------------------------------------------------
def model_to_dict(model: TPPCModel, space: Optional[TuningSpace] = None,
                  kind: Optional[str] = None) -> Dict:
    """Serialize a trained model (plus its space's parameters) to JSON-safe
    primitives.  ``space`` defaults to the model's own space; ``kind`` is
    the problem kind recorded in the artifact's structural signature
    (store save paths pass their key's kind)."""
    space = space if space is not None else model.space
    out = {"format": FORMAT, "version": VERSION,
           "space": space_to_dict(space)}
    if isinstance(model, DecisionTreeModel):
        out["kind"] = "tree"
        out["trees"] = {name: _node_to_dict(t)
                        for name, t in model.trees.items()}
        out["scale"] = {name: float(s) for name, s in model.scale.items()}
    elif isinstance(model, QuadraticRegressionModel):
        out["kind"] = "quadratic"
        out["counter_names"] = list(model.counter_names)
        out["coefs"] = {
            ",".join(str(int(b)) for b in key): {
                name: [float(x) for x in coef]
                for name, coef in per_counter.items()
            }
            for key, per_counter in model.coefs.items()
        }
        out["fallback"] = {name: float(v)
                           for name, v in model._fallback.items()}
    elif isinstance(model, ExactCounterModel):
        out["kind"] = "exact"
        # pair configs and counters from the same enumeration: the bound
        # space's.  ``predict_index`` routes through the space→record remap,
        # so re-serializing a ``from_pairs`` model whose space enumerates
        # differently from the original artifact stays aligned (writing the
        # raw record list here would silently shuffle the pairs).
        out["configs"] = [model.space[i] for i in range(len(model.space))]
        out["counters"] = [
            {name: float(v) for name, v in model.predict_index(i).items()}
            for i in range(len(model.space))
        ]
    else:
        raise TypeError(f"cannot serialize model type {type(model).__name__}")
    sig = getattr(model, "signature", None)
    if isinstance(sig, SpaceSignature) and (kind is None or sig.kind == kind):
        out["signature"] = sig.to_dict()
    else:
        base_kind = kind if kind is not None else \
            (sig.kind if isinstance(sig, SpaceSignature) else "kernel")
        out["signature"] = SpaceSignature.from_space(
            space, kind=str(base_kind),
            counters=model.counter_names).to_dict()
    return out


def model_from_dict(d: Dict, space: Optional[TuningSpace] = None) -> TPPCModel:
    """Reconstruct a trained model.  Pass ``space`` to bind the model to an
    existing (possibly constraint-pruned) space; otherwise the parameters
    recorded in the artifact are used to rebuild one."""
    if d.get("format") != FORMAT:
        raise ValueError(f"not a {FORMAT} artifact: format={d.get('format')!r}")
    if d.get("version") != VERSION:
        raise ValueError(f"unsupported {FORMAT} version {d.get('version')!r}")
    if space is not None:
        _check_space_compatible(space, d["space"])
    else:
        space = space_from_dict(d["space"])
    kind = d["kind"]
    if kind == "tree":
        trees = {name: _node_from_dict(t) for name, t in d["trees"].items()}
        scale = {name: float(s) for name, s in d["scale"].items()}
        model: TPPCModel = DecisionTreeModel.from_state(space, trees, scale)
    elif kind == "quadratic":
        coefs = {
            tuple(int(b) for b in key.split(",") if b != ""): {
                name: np.asarray(coef, dtype=np.float64)
                for name, coef in per_counter.items()
            }
            for key, per_counter in d["coefs"].items()
        }
        model = QuadraticRegressionModel.from_state(
            space, d["counter_names"], coefs, d["fallback"])
    elif kind == "exact":
        model = ExactCounterModel.from_pairs(space, d["configs"], d["counters"])
    else:
        raise ValueError(f"unknown model kind {kind!r}")
    model.signature = artifact_signature(d)
    return model
