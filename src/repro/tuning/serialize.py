"""JSON round-trip for trained TP→PC_ops models — the portability artifact.

The paper's headline claim is that a model trained on one GPU/input steers
autotuning on another.  ``model_to_dict``/``model_from_dict`` turn that
claim into a shippable file: train anywhere, ``TuningSession.save_model``,
copy the JSON to the machine of interest, ``load_model`` and tune.

Serialized alongside the model are the tuning-space *parameters* (names and
value lists) — everything the models need to vectorize configurations.
Space constraints are predicates and are NOT serialized; tree/quadratic
models never consult space indexing, and exact models carry their own
explicit (config, counters) pairs, so reconstruction is faithful either way.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.core.model import (DecisionTreeModel, ExactCounterModel,
                              QuadraticRegressionModel, TPPCModel, _Node)
from repro.core.tuning_space import TuningParameter, TuningSpace

FORMAT = "repro.tppc_model"
VERSION = 1


# -- tuning space (parameters only) -------------------------------------------
def space_to_dict(space: TuningSpace) -> Dict:
    return {
        "name": space.name,
        "parameters": [
            {"name": p.name, "values": list(p.values)}
            for p in space.parameters
        ],
    }


def space_from_dict(d: Dict) -> TuningSpace:
    return TuningSpace(
        [TuningParameter(p["name"], tuple(p["values"]))
         for p in d["parameters"]],
        name=d.get("name", "space"),
    )


# -- decision trees ------------------------------------------------------------
def _node_to_dict(n: _Node) -> Dict:
    if n.is_leaf:
        return {"value": n.value}
    return {
        "value": n.value,
        "feature": n.feature,
        "threshold": n.threshold,
        "left": _node_to_dict(n.left),
        "right": _node_to_dict(n.right),
    }


def _node_from_dict(d: Dict) -> _Node:
    node = _Node(value=float(d["value"]))
    if "feature" in d:
        node.feature = int(d["feature"])
        node.threshold = float(d["threshold"])
        node.left = _node_from_dict(d["left"])
        node.right = _node_from_dict(d["right"])
    return node


def _check_space_compatible(space: TuningSpace, space_dict: Dict) -> None:
    """Models vectorize configs by the bound space's parameter order and
    value lists — a mismatch would silently mispredict, so refuse it."""
    ours = [(p.name, list(p.values)) for p in space.parameters]
    theirs = [(p["name"], list(p["values"])) for p in space_dict["parameters"]]
    if ours != theirs:
        raise ValueError(
            "model artifact was trained on an incompatible tuning space: "
            f"artifact parameters {theirs} vs target space {ours}")


# -- model <-> dict ------------------------------------------------------------
def model_to_dict(model: TPPCModel, space: Optional[TuningSpace] = None) -> Dict:
    """Serialize a trained model (plus its space's parameters) to JSON-safe
    primitives.  ``space`` defaults to the model's own space."""
    space = space if space is not None else model.space
    out = {"format": FORMAT, "version": VERSION,
           "space": space_to_dict(space)}
    if isinstance(model, DecisionTreeModel):
        out["kind"] = "tree"
        out["trees"] = {name: _node_to_dict(t)
                        for name, t in model.trees.items()}
        out["scale"] = {name: float(s) for name, s in model.scale.items()}
    elif isinstance(model, QuadraticRegressionModel):
        out["kind"] = "quadratic"
        out["counter_names"] = list(model.counter_names)
        out["coefs"] = {
            ",".join(str(int(b)) for b in key): {
                name: [float(x) for x in coef]
                for name, coef in per_counter.items()
            }
            for key, per_counter in model.coefs.items()
        }
        out["fallback"] = {name: float(v)
                           for name, v in model._fallback.items()}
    elif isinstance(model, ExactCounterModel):
        out["kind"] = "exact"
        # pair configs and counters from the same enumeration: the bound
        # space's.  ``predict_index`` routes through the space→record remap,
        # so re-serializing a ``from_pairs`` model whose space enumerates
        # differently from the original artifact stays aligned (writing the
        # raw record list here would silently shuffle the pairs).
        out["configs"] = [model.space[i] for i in range(len(model.space))]
        out["counters"] = [
            {name: float(v) for name, v in model.predict_index(i).items()}
            for i in range(len(model.space))
        ]
    else:
        raise TypeError(f"cannot serialize model type {type(model).__name__}")
    return out


def model_from_dict(d: Dict, space: Optional[TuningSpace] = None) -> TPPCModel:
    """Reconstruct a trained model.  Pass ``space`` to bind the model to an
    existing (possibly constraint-pruned) space; otherwise the parameters
    recorded in the artifact are used to rebuild one."""
    if d.get("format") != FORMAT:
        raise ValueError(f"not a {FORMAT} artifact: format={d.get('format')!r}")
    if d.get("version") != VERSION:
        raise ValueError(f"unsupported {FORMAT} version {d.get('version')!r}")
    if space is not None:
        _check_space_compatible(space, d["space"])
    else:
        space = space_from_dict(d["space"])
    kind = d["kind"]
    if kind == "tree":
        trees = {name: _node_from_dict(t) for name, t in d["trees"].items()}
        scale = {name: float(s) for name, s in d["scale"].items()}
        return DecisionTreeModel.from_state(space, trees, scale)
    if kind == "quadratic":
        coefs = {
            tuple(int(b) for b in key.split(",") if b != ""): {
                name: np.asarray(coef, dtype=np.float64)
                for name, coef in per_counter.items()
            }
            for key, per_counter in d["coefs"].items()
        }
        return QuadraticRegressionModel.from_state(
            space, d["counter_names"], coefs, d["fallback"])
    if kind == "exact":
        return ExactCounterModel.from_pairs(space, d["configs"], d["counters"])
    raise ValueError(f"unknown model kind {kind!r}")
