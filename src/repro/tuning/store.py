"""``ConfigStore`` — persistent tuned-config + model-artifact store.

The paper's motivation (ii): autotuning must be *repeated* whenever the
processed-data characteristics change, and a portable TP→PC model makes each
repetition cheap.  In a serving system that repetition happens online — the
request mix shifts, the engine retunes — so the results must outlive the
process: the second time a workload shape shows up (or the service restarts)
the tuned configuration is reused with ZERO live trials.

The store is one JSON file holding two artifact kinds under the same key
``(problem kind, space name, input-shape bucket, hardware)``:

* **entries** — tuned configurations (`config`, `runtime`, `trials`, free-form
  `meta`), written by the online tuner after live trials;
* **models**  — trained TP→PC_ops model artifacts in the
  ``repro.tuning.serialize`` JSON format, so the warm-start ranking that
  keeps live-trial counts small is itself persistent and shippable across
  machines (``TuningSession.save_model_to_store``/``load_model_from_store``).
  Every stored artifact carries a monotonic ``revision`` (and optional
  ``n_obs``): merge conflicts between writers resolve to the higher
  revision, so a model retrained on newer data supersedes its stale
  ancestor instead of tying.  ``prune(keep_hardware=..., keep_spaces=...,
  keep_buckets=...)`` GCs artifacts for fleet members that no longer exist.

Model artifacts carry a structural **space signature**
(``repro.tuning.signature``) so the warm-start ladder has a fifth,
cross-space tier: when no model of the exact space exists, the most
*structurally similar* same-kind space's model is rebound onto the new
space through the shared-counter intersection
(``nearest_transfer_key`` / ``load_transfer_model``).  Version-2 files
(signature-less artifacts) load fine — signatures are recomputed from
the recorded space parameters on the way in and persisted by the next
save.

Schema (``format: repro.config_store``, version 3)::

    {
      "format": "repro.config_store",
      "version": 2,
      "entries": {
        "serve|serve_online|p1n1|tpu_v5e": {
          "kind": "serve", "space": "serve_online", "bucket": "p1n1",
          "hardware": "tpu_v5e",
          "config": {"BATCH": 8, "MAX_SEQ": 64},
          "runtime": 0.0123,          # best measured seconds
          "trials": 6,                # live empirical tests spent tuning it
          "meta": {...}               # free-form (e.g. ask-tell history)
        }, ...
      },
      "models": { "<same key>": <repro.tppc_model artifact>, ... }
    }

The leading ``kind`` field namespaces keys by *problem kind* (the
``TuningProblem`` registry string: "kernel", "serve", "sharding", ...) so
artifacts from different problem kinds never collide even when their
space names do.  Version-1 files (3-part ``space|bucket|hardware`` keys)
still load and merge: legacy keys upgrade on the way in, with the kind
inferred from the space name (``legacy_kind``) — serve-autotuner spaces
were the only non-kernel artifacts that existed before version 2.

Writes are atomic (tempfile + ``os.replace``) and auto-saved when the store
is bound to a path; ``ConfigStore()`` with no path is a process-local cache
with the same API.

Concurrent writers are safe: ``save()`` takes an advisory file lock
(``<path>.lock``) and read-merge-writes — entries and models that other
processes persisted since our last load are merged in before the atomic
replace (conflicting tuned configs resolve to the better runtime), so a
fleet of tuner processes sharing one store never clobber each other.
"""
from __future__ import annotations

import bisect
import dataclasses
import json
import os
import sys
import tempfile
import time
import zlib
from typing import Any, Dict, Iterator, List, Optional, Tuple

try:
    import fcntl
except ImportError:          # non-POSIX: degrade to atomic-replace only
    fcntl = None

from repro.core.model import TPPCModel, TransferredModel
from repro.core.tuning_space import Config, TuningSpace
from repro.tuning.serialize import (artifact_signature, ensure_signature,
                                    model_from_dict, model_to_dict,
                                    rebind_model_dict)
from repro.tuning.signature import (DEFAULT_TRANSFER_THRESHOLD,
                                    SpaceSignature, similarity,
                                    transfer_compatible)

FORMAT = "repro.config_store"
VERSION = 3
# versions this code can read and merge (v1: 3-part keys, no kind;
# v2: kind|space|bucket|hardware keys, signature-less model artifacts)
READABLE_VERSIONS = (1, 2, 3)
_SEP = "|"
DEFAULT_KIND = "kernel"


def content_crc(entries: Dict[str, Any], models: Dict[str, Any]) -> int:
    """crc32 over the store's canonical content JSON.

    Saved as the top-level ``crc`` field; verified on load so a torn
    write or bit rot is detected instead of silently adopted.  Files
    written before checksumming (no ``crc`` field) still load.
    """
    return zlib.crc32(json.dumps(
        {"entries": entries, "models": models},
        separators=(",", ":"), sort_keys=True).encode("utf-8"))


def quarantine_file(path: str, why: str) -> str:
    """Move a damaged artifact aside as ``<path>.corrupt`` and log it.

    Never clobbers an earlier quarantine (numeric suffixes) and never
    raises — worst case the damaged file stays in place and the caller
    proceeds without it anyway.  Returns the destination (or ``path``
    itself when the move failed).
    """
    dest = path + ".corrupt"
    n = 1
    while os.path.exists(dest):
        dest = f"{path}.corrupt.{n}"
        n += 1
    try:
        os.replace(path, dest)
    except OSError:
        dest = path
    print(f"[store] quarantined {path} -> {dest}: {why}", file=sys.stderr)
    return dest


def legacy_kind(space: str) -> str:
    """Problem kind a pre-v2 (kind-less) key implies from its space name.

    Before the ``TuningProblem`` refactor only two artifact producers
    existed: the serve autotuner (space ``serve_online`` / ``serve*``)
    and kernel tuning (everything else)."""
    return "serve" if str(space).startswith("serve") else DEFAULT_KIND


def store_key(space: str, bucket: str, hardware: str,
              kind: Optional[str] = None) -> str:
    """Canonical ``kind|space|bucket|hardware`` key (no field contains |).

    ``kind=None`` infers the problem kind from the space name via
    ``legacy_kind`` — exactly the rule version-1 keys upgrade under, so
    pre-refactor call sites keep resolving to the same artifacts."""
    parts = (str(kind if kind is not None else legacy_kind(space)),
             str(space), str(bucket), str(hardware))
    for p in parts:
        if _SEP in p:
            raise ValueError(f"store key field {p!r} contains {_SEP!r}")
    return _SEP.join(parts)


def split_key(key: str) -> Tuple[str, str, str, str]:
    """``(kind, space, bucket, hardware)`` of a store key, tolerating the
    3-part version-1 form (kind inferred via ``legacy_kind``)."""
    parts = str(key).split(_SEP)
    if len(parts) == 4:
        return parts[0], parts[1], parts[2], parts[3]
    if len(parts) == 3:
        return legacy_kind(parts[0]), parts[0], parts[1], parts[2]
    raise ValueError(f"malformed store key {key!r}")


def upgrade_key(key: str) -> str:
    """The version-2 form of any (possibly version-1) store key."""
    kind, space, bucket, hardware = split_key(key)
    return store_key(space, bucket, hardware, kind=kind)


class _FileLock:
    """Advisory exclusive lock for the store's read-merge-write section.

    POSIX ``flock`` on a sidecar ``<path>.lock`` file (never on the store
    file itself — the atomic ``os.replace`` would swap the locked inode out
    from under us).  Degrades to a no-op where ``fcntl`` is unavailable, in
    which case only single-writer atomicity is guaranteed.
    """

    def __init__(self, path: str):
        self.lock_path = path + ".lock"
        self._fd: Optional[int] = None

    def __enter__(self) -> "_FileLock":
        if fcntl is not None:
            self._fd = os.open(self.lock_path,
                               os.O_CREAT | os.O_RDWR, 0o644)
            fcntl.flock(self._fd, fcntl.LOCK_EX)
        return self

    def __exit__(self, *exc) -> None:
        if self._fd is not None:
            fcntl.flock(self._fd, fcntl.LOCK_UN)
            os.close(self._fd)
            self._fd = None


@dataclasses.dataclass(frozen=True)
class StoreEntry:
    """One tuned configuration for one (kind, space, bucket, hardware)."""

    space: str
    bucket: str
    hardware: str
    config: Config
    runtime: float              # best measured seconds at tuning time
    trials: int                 # live empirical tests spent finding it
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)
    kind: str = ""              # "" => inferred from the space name

    def __post_init__(self):
        if not self.kind:
            object.__setattr__(self, "kind", legacy_kind(self.space))

    @property
    def key(self) -> str:
        return store_key(self.space, self.bucket, self.hardware,
                         kind=self.kind)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "kind": self.kind, "space": self.space, "bucket": self.bucket,
            "hardware": self.hardware, "config": dict(self.config),
            "runtime": float(self.runtime), "trials": int(self.trials),
            "meta": self.meta,
        }

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "StoreEntry":
        return StoreEntry(
            space=d["space"], bucket=d["bucket"], hardware=d["hardware"],
            config=dict(d["config"]), runtime=float(d["runtime"]),
            trials=int(d["trials"]), meta=dict(d.get("meta", {})),
            kind=str(d.get("kind", "")),   # v1 entry dicts carry no kind
        )


class ConfigStore:
    """JSON-backed artifact store for tuned configs and TP→PC models.

    ``path=None`` keeps everything in memory (same API, nothing persisted);
    with a path, the file is loaded if it exists and every ``put`` /
    ``put_model`` re-saves atomically.
    """

    def __init__(self, path: Optional[str] = None, autosave: bool = True):
        self.path = path
        self.autosave = autosave
        self._entries: Dict[str, StoreEntry] = {}
        self._models: Dict[str, Dict] = {}
        # (kind, space) -> sorted model keys: nearest_model_key and the
        # transfer tier scan one bucket instead of the whole corpus
        self._model_index: Dict[Tuple[str, str], List[str]] = {}
        # model key -> parsed SpaceSignature (or None when unsignable),
        # invalidated whenever the key mutates
        self._sig_cache: Dict[str, Optional[SpaceSignature]] = {}
        self.quarantined: List[str] = []   # damaged files moved aside
        # delta-save bookkeeping: keys mutated since the last save to
        # self.path, and a stat token identifying our own last write
        self._dirty_entries: set = set()
        self._dirty_models: set = set()
        self._disk_token: Optional[Tuple[int, int, int]] = None
        self.save_stats: Dict[str, Any] = {
            "saves": 0,        # save() calls
            "noop": 0,         # clean saves skipped entirely
            "full": 0,         # full serialize-everything writes
            "delta": 0,        # dirty-key overlay writes
            "merged_reads": 0,  # saves that read+merged a changed file
            "last_s": 0.0, "total_s": 0.0,
        }
        if path is not None and os.path.exists(path):
            self.load(path)

    # -- tuned configs ---------------------------------------------------------
    def get(self, space: str, bucket: str, hardware: str,
            kind: Optional[str] = None) -> Optional[StoreEntry]:
        return self._entries.get(store_key(space, bucket, hardware,
                                           kind=kind))

    def put(self, space: str, bucket: str, hardware: str, config: Config,
            runtime: float, trials: int,
            meta: Optional[Dict[str, Any]] = None,
            kind: Optional[str] = None) -> StoreEntry:
        """Record a tuned config; the merge rule applies at put time.

        An existing entry with a strictly better (lower) runtime wins
        over the incoming one — the same resolution ``_merge_from``
        applies between files.  Resolving here keeps memory monotone,
        which the own-write save fast path depends on: it serializes
        memory without re-reading the file, so memory must never hold a
        worse value than anything already persisted."""
        entry = StoreEntry(space=space, bucket=bucket, hardware=hardware,
                           config=dict(config), runtime=float(runtime),
                           trials=int(trials), meta=dict(meta or {}),
                           kind=kind or "")
        prev = self._entries.get(entry.key)
        if prev is not None and prev.runtime < entry.runtime:
            return prev
        self._entries[entry.key] = entry
        self._dirty_entries.add(entry.key)
        self._autosave()
        return entry

    def entries(self) -> Iterator[StoreEntry]:
        return iter(self._entries.values())

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: str) -> bool:
        return key in self._entries

    # -- model index -----------------------------------------------------------
    # The model corpus is bucketed by (kind, space) and each bucket kept
    # sorted, so every warm-start lookup — and the cross-space transfer
    # scan — walks only the keys that can possibly match instead of
    # re-sorting and re-splitting the whole corpus per call.  ALL
    # ``self._models`` mutations must go through these helpers (or
    # ``_reindex_models`` after a bulk swap).
    def _index_add(self, key: str) -> None:
        kind, space, _, _ = split_key(key)
        keys = self._model_index.setdefault((kind, space), [])
        i = bisect.bisect_left(keys, key)
        if i >= len(keys) or keys[i] != key:
            keys.insert(i, key)
        self._sig_cache.pop(key, None)

    def _index_discard(self, key: str) -> None:
        kind, space, _, _ = split_key(key)
        keys = self._model_index.get((kind, space))
        if keys:
            i = bisect.bisect_left(keys, key)
            if i < len(keys) and keys[i] == key:
                keys.pop(i)
            if not keys:
                del self._model_index[(kind, space)]
        self._sig_cache.pop(key, None)

    def _reindex_models(self) -> None:
        self._model_index = {}
        self._sig_cache = {}
        for k in sorted(self._models):
            kind, space, _, _ = split_key(k)
            self._model_index.setdefault((kind, space), []).append(k)

    def model_signature(self, key: str) -> Optional[SpaceSignature]:
        """Parsed structural signature of a stored artifact (cached), or
        None when the key is absent or the artifact has no recoverable
        structure."""
        if key not in self._models:
            return None
        if key in self._sig_cache:
            return self._sig_cache[key]
        sig = artifact_signature(self._models[key], kind=split_key(key)[0])
        self._sig_cache[key] = sig
        return sig

    # -- model artifacts -------------------------------------------------------
    def get_model_dict(self, space: str, bucket: str, hardware: str,
                       kind: Optional[str] = None) -> Optional[Dict]:
        return self._models.get(store_key(space, bucket, hardware,
                                          kind=kind))

    def model_keys(self) -> Iterator[str]:
        """All stored model-artifact keys (``kind|space|bucket|hardware``)."""
        return iter(self._models)

    def put_model_dict(self, space: str, bucket: str, hardware: str,
                       artifact: Dict,
                       revision: Optional[int] = None,
                       n_obs: Optional[int] = None,
                       kind: Optional[str] = None) -> None:
        """Store a model artifact under a MONOTONIC ``revision``.

        A model retrained on more observations must supersede its stale
        ancestor when two writers merge — runtime ties can't order
        artifacts, so every stored artifact carries ``revision``
        (defaults to ``existing revision + 1``, so retraining under the
        same key always moves forward) and optionally ``n_obs`` (how many
        observations trained it, informational).  ``_merge_from`` resolves
        model conflicts by the higher revision — and so does this method:
        a put with an explicitly LOWER revision than the artifact already
        in memory is a stale write and loses immediately, which keeps
        memory monotone for the own-write save fast path (memory is
        serialized without re-reading the file, so it must never hold a
        lower revision than anything already persisted).
        """
        key = store_key(space, bucket, hardware, kind=kind)
        artifact = ensure_signature(dict(artifact), kind=split_key(key)[0])
        prev = self._models.get(key)
        if revision is None:
            revision = int((prev or {}).get("revision", 0)) + 1
        artifact["revision"] = int(revision)
        if n_obs is not None:
            artifact["n_obs"] = int(n_obs)
        if prev is not None \
                and int(prev.get("revision", 0)) > artifact["revision"]:
            return
        self._models[key] = artifact
        self._index_add(key)
        self._dirty_models.add(key)
        self._autosave()

    def load_model(self, space: str, bucket: str, hardware: str,
                   bind_space: Optional[TuningSpace] = None,
                   kind: Optional[str] = None) -> Optional[TPPCModel]:
        """Reconstruct a stored model, optionally bound to an existing space
        (compatibility-checked by the serializer)."""
        d = self.get_model_dict(space, bucket, hardware, kind=kind)
        if d is None:
            return None
        return model_from_dict(d, space=bind_space)

    def save_model(self, space: str, bucket: str, hardware: str,
                   model: TPPCModel,
                   model_space: Optional[TuningSpace] = None,
                   revision: Optional[int] = None,
                   n_obs: Optional[int] = None,
                   kind: Optional[str] = None) -> None:
        self.put_model_dict(
            space, bucket, hardware,
            model_to_dict(model, model_space,
                          kind=kind if kind is not None
                          else legacy_kind(space)),
            revision=revision, n_obs=n_obs, kind=kind)

    def nearest_model_key(self, space: str, bucket: str, hardware: str,
                          kind: Optional[str] = None) -> Optional[str]:
        """Best stored-model key for ``(kind, space, bucket, hardware)``.

        Preference order mirrors the paper's portability claims: exact hit;
        same bucket on other hardware (PC_ops predictions are
        hardware-independent — §4.4's cross-GPU scenario); same hardware on
        another input bucket (§4.5's cross-input scenario); any model of the
        same space.  The scan never crosses problem kinds — a serve-space
        model must not warm-start a kernel job that happens to share the
        space name.  Ties break deterministically (sorted key order).
        ``None`` when no model of the kind+space exists.
        """
        kind = kind if kind is not None else legacy_kind(space)
        exact = store_key(space, bucket, hardware, kind=kind)
        if exact in self._models:
            return exact
        first_bucket = first_hw = first_space = None
        # one index bucket holds exactly the kind+space keys, pre-sorted,
        # so the legacy tie-break (first key in sorted order per tier)
        # is preserved without touching the rest of the corpus
        for k in self._model_index.get((kind, space), ()):
            _, _, b, h = split_key(k)
            if b == bucket:
                if first_bucket is None:
                    first_bucket = k
                    break                      # best possible tier: done
            elif h == hardware:
                if first_hw is None:
                    first_hw = k
            elif first_space is None:
                first_space = k
        for k in (first_bucket, first_hw, first_space):
            if k is not None:
                return k
        return None

    def transfer_candidates(self, signature: SpaceSignature,
                            bucket: str, hardware: str,
                            threshold: float = DEFAULT_TRANSFER_THRESHOLD
                            ) -> List[Tuple[str, float]]:
        """Every compatible-space model key, most preferred first.

        Scans same-kind index buckets for OTHER spaces (the four legacy
        tiers own the exact space), gates each artifact through
        ``transfer_compatible`` and ranks survivors by similarity — ties
        broken toward the same bucket, then the same hardware, then
        sorted key order.  One entry per (space, bucket, hardware) key;
        empty when nothing clears the threshold (transfer never engages
        on a weak match)."""
        found: List[Tuple[Tuple, str, float]] = []
        for (kk, s), keys in sorted(self._model_index.items()):
            if kk != signature.kind or s == signature.space:
                continue
            for k in keys:
                sig = self.model_signature(k)
                if sig is None \
                        or not transfer_compatible(sig, signature,
                                                   threshold=threshold):
                    continue
                sim = similarity(sig, signature)
                _, _, b, h = split_key(k)
                rank = (-sim, 0 if b == bucket else 1,
                        0 if h == hardware else 1, k)
                found.append((rank, k, sim))
        found.sort(key=lambda t: t[0])
        return [(k, sim) for _, k, sim in found]

    def nearest_transfer_key(self, signature: SpaceSignature,
                             bucket: str, hardware: str,
                             threshold: float = DEFAULT_TRANSFER_THRESHOLD
                             ) -> Optional[Tuple[str, float]]:
        """Fifth warm-start tier: best *compatible-space* model key, or
        ``None`` when nothing clears the threshold (see
        ``transfer_candidates`` for the full ranking)."""
        cands = self.transfer_candidates(signature, bucket, hardware,
                                         threshold=threshold)
        return cands[0] if cands else None

    def load_nearest_model(self, space: str, bucket: str, hardware: str,
                           bind_space: Optional[TuningSpace] = None,
                           kind: Optional[str] = None
                           ) -> Tuple[Optional[TPPCModel], Optional[str]]:
        """``(model, key)`` for the nearest stored artifact (None, None on
        miss) — the fleet's warm-start hook."""
        key = self.nearest_model_key(space, bucket, hardware, kind=kind)
        if key is None:
            return None, None
        return model_from_dict(self._models[key], space=bind_space), key

    def load_transfer_model(self, signature: SpaceSignature,
                            bucket: str, hardware: str,
                            bind_space: TuningSpace,
                            threshold: float = DEFAULT_TRANSFER_THRESHOLD
                            ) -> Tuple[Optional[TransferredModel],
                                       Optional[str], float]:
        """``(model, key, similarity)`` for the best compatible-space
        artifact, rebound onto ``bind_space`` through the shared-counter
        intersection — ``(None, None, 0.0)`` when no stored model clears
        the threshold.  Only consulted after all four exact-space tiers
        miss, so exact warm-start behavior is untouched."""
        found = self.nearest_transfer_key(signature, bucket, hardware,
                                          threshold=threshold)
        if found is None:
            return None, None, 0.0
        key, sim = found
        try:
            model = rebind_model_dict(self._models[key], bind_space,
                                      signature, source_key=key,
                                      similarity=sim)
        except (ValueError, KeyError, TypeError):
            # an artifact that gates as compatible but cannot rebind
            # (e.g. empty shared-counter set) is a miss, not a crash
            return None, None, 0.0
        return model, key, sim

    def load_transfer_ensemble(self, signature: SpaceSignature,
                               bucket: str, hardware: str,
                               bind_space: TuningSpace,
                               threshold: float
                               = DEFAULT_TRANSFER_THRESHOLD,
                               limit: Optional[int] = None
                               ) -> Tuple[Optional["TransferEnsemble"],
                                          Optional[str], float]:
        """``(ensemble, top_key, top_similarity)`` over EVERY
        compatible-space artifact, each rebound onto ``bind_space`` —
        ``(None, None, 0.0)`` when no stored model clears the threshold.

        The similarity-weighted committee beats the single most-similar
        source at the head of the ranking (where a warm start spends its
        trials): structure every compatible space agrees on is exactly
        what generalizes.  Candidates that gate as compatible but cannot
        rebind are skipped, not fatal.  ``limit`` caps the committee at
        the N most preferred sources (None: all)."""
        from repro.core.model import TransferEnsemble

        members = []
        for key, sim in self.transfer_candidates(signature, bucket,
                                                 hardware,
                                                 threshold=threshold):
            try:
                members.append((rebind_model_dict(
                    self._models[key], bind_space, signature,
                    source_key=key, similarity=sim), sim))
            except (ValueError, KeyError, TypeError):
                continue
            if limit is not None and len(members) >= limit:
                break
        if not members:
            return None, None, 0.0
        return TransferEnsemble(members), members[0][0].source_key, \
            members[0][1]

    # -- persistence -----------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        entries = {k: e.to_dict() for k, e in sorted(self._entries.items())}
        models = {k: m for k, m in sorted(self._models.items())}
        return {
            "format": FORMAT,
            "version": VERSION,
            "crc": content_crc(entries, models),
            "entries": entries,
            "models": models,
        }

    def save(self, path: Optional[str] = None, merge: bool = True,
             _post_merge=None, force: bool = False) -> str:
        """Locked read-merge-write, then atomic replace — amortized.

        Under the file lock, entries/models persisted by OTHER writers since
        our last load are merged into memory first (``_merge_from``), so
        concurrent tuner processes sharing one store file never clobber each
        other's keys; ``merge=False`` restores plain last-writer-wins
        overwrite semantics (e.g. to intentionally reset a store).
        ``_post_merge`` (internal) runs after the merge and before the
        write — ``prune`` uses it to re-apply its filter so the on-disk
        copy of a pruned key is not immediately re-adopted.

        The store tracks which keys changed since the last save, which
        buys three hot-path shortcuts (``force=True`` disables all of
        them and always rewrites):

        * **clean no-op** — nothing dirty means the locked
          read-merge-write would only reproduce the file: skip it;
        * **own-write fast path** — when the file's stat token still
          matches our last write (single-writer case), skip the
          read-back + checksum + merge and just serialize memory;
        * **delta write** — when the file DID change under us, merge it
          in, then build the new payload by overlaying only the dirty
          keys onto the raw on-disk dicts, so unchanged entries/models
          skip re-serialization.
        """
        t0 = time.perf_counter()
        path = path if path is not None else self.path
        if path is None:
            raise ValueError("ConfigStore has no path; pass save(path=...)")
        same = path == self.path
        st = self.save_stats
        st["saves"] += 1
        dirty = bool(self._dirty_entries or self._dirty_models)
        if same and not dirty and not force and merge \
                and _post_merge is None and os.path.exists(path):
            # nothing of ours needs writing.  If the file still carries
            # our own last write, the whole call is a no-op; if another
            # writer changed it, refresh memory from disk (the merge
            # side effect callers rely on) but skip the rewrite — a
            # merge-respecting peer never holds worse values than ours.
            if self._disk_token is not None \
                    and self._stat_token(path) == self._disk_token:
                st["noop"] += 1
                return path
            with _FileLock(path):
                on_disk = self._read_checked(path)
                if on_disk is not None:
                    self._merge_from(on_disk)
                    st["merged_reads"] += 1
                self._disk_token = self._stat_token(path)
            st["noop"] += 1
            st["last_s"] = round(time.perf_counter() - t0, 9)
            st["total_s"] = round(st["total_s"] + st["last_s"], 9)
            return path
        with _FileLock(path):
            on_disk: Optional[Dict[str, Any]] = None
            if merge and os.path.exists(path):
                unchanged = (same and not force
                             and self._disk_token is not None
                             and self._stat_token(path) == self._disk_token)
                if not unchanged:
                    on_disk = self._read_checked(path)
                    if on_disk is not None:
                        self._merge_from(on_disk)
                        st["merged_reads"] += 1
            if _post_merge is not None:
                _post_merge()
            delta_ok = (same and not force and merge
                        and _post_merge is None
                        and on_disk is not None
                        and on_disk.get("version") == VERSION)
            if delta_ok:
                payload = self._delta_payload(on_disk)
                st["delta"] += 1
            else:
                payload = self.to_dict()
                st["full"] += 1
            self._write_atomic(path, payload)
            if same:
                self._dirty_entries.clear()
                self._dirty_models.clear()
                self._disk_token = self._stat_token(path)
            else:
                # a copy elsewhere must not launder dirtiness away from
                # self.path — and keys adopted from the foreign file
                # have to reach self.path on the next save too
                self._dirty_entries |= set(self._entries)
                self._dirty_models |= set(self._models)
        st["last_s"] = round(time.perf_counter() - t0, 9)
        st["total_s"] = round(st["total_s"] + st["last_s"], 9)
        return path

    @staticmethod
    def _stat_token(path: str) -> Optional[Tuple[int, int, int]]:
        """Identity of the file's current bytes.

        (inode, mtime_ns, size) alone is forgeable under rapid
        alternating writers: mkstemp recycles the just-freed inode, the
        kernel stamps mtime from the coarse (jiffy-granularity) clock,
        and two writers' payloads can match in size — so
        ``_write_atomic`` re-stamps every write with a true
        nanosecond-resolution mtime, which makes a token collision
        require two processes writing within the same nanosecond."""
        try:
            s = os.stat(path)
            return (s.st_ino, s.st_mtime_ns, s.st_size)
        except OSError:
            return None

    def _delta_payload(self, on_disk: Dict[str, Any]) -> Dict[str, Any]:
        """Merged payload from overlaying only the DIRTY keys onto the
        raw on-disk dicts (memory already holds the merged values, so a
        dirty key that lost its conflict writes back the disk value).
        A dirty key missing from memory (pruned, unsaved) is skipped —
        same outcome a full merging save would produce."""
        entries = dict(on_disk.get("entries", {}))
        models = dict(on_disk.get("models", {}))
        for k in self._dirty_entries:
            e = self._entries.get(k)
            if e is not None:
                entries[k] = e.to_dict()
        for k in self._dirty_models:
            m = self._models.get(k)
            if m is not None:
                models[k] = m
        entries = {k: entries[k] for k in sorted(entries)}
        models = {k: models[k] for k in sorted(models)}
        return {"format": FORMAT, "version": VERSION,
                "crc": content_crc(entries, models),
                "entries": entries, "models": models}

    @staticmethod
    def _write_atomic(path: str, payload: Dict[str, Any]) -> None:
        d = os.path.dirname(os.path.abspath(path)) or "."
        fd, tmp = tempfile.mkstemp(prefix=".config_store.", dir=d)
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=1)
            # the kernel's coarse clock can give back-to-back writes
            # identical mtimes; a true-ns stamp (after the close-flush,
            # which would re-stamp) keeps _stat_token honest (see its
            # docstring)
            t = time.time_ns()
            os.utime(tmp, ns=(t, t))
            os.replace(tmp, path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    def _merge_from(self, d: Dict[str, Any]) -> None:
        """Fold another store's dict into memory (the read-merge step).

        Unknown keys are adopted; a tuned-config conflict resolves to the
        better (lower) runtime — the fleet semantics: whoever found the
        faster configuration for a key wins.  A model conflict resolves to
        the HIGHER ``revision`` (a model retrained on more observations
        supersedes its stale ancestor; runtimes can't order artifacts);
        ties — including legacy revision-less artifacts — keep ours.

        Version-1 dicts merge too: their 3-part keys upgrade to the
        ``kind|...`` form on the way in (``upgrade_key``), so a daemon
        running this code can share a corpus with files written before
        the refactor.
        """
        if d.get("format") != FORMAT \
                or d.get("version") not in READABLE_VERSIONS:
            raise ValueError(
                f"refusing to merge non-{FORMAT}-v{READABLE_VERSIONS} file "
                f"(format={d.get('format')!r} version={d.get('version')!r})")
        for k, e in d.get("entries", {}).items():
            other = StoreEntry.from_dict(e)
            k = upgrade_key(k)
            mine = self._entries.get(k)
            if mine is None or other.runtime < mine.runtime:
                self._entries[k] = other
        for k, m in d.get("models", {}).items():
            k = upgrade_key(k)
            mine = self._models.get(k)
            if mine is None or int(m.get("revision", 0)) \
                    > int(mine.get("revision", 0)):
                # pre-v3 artifacts carry no signature: compute one from
                # the recorded parameters so the transfer tier sees them
                self._models[k] = ensure_signature(m, kind=split_key(k)[0])
                self._index_add(k)

    def prune(self, keep_hardware=None, keep_spaces=None,
              keep_buckets=None, keep_kinds=None,
              dry_run: bool = False) -> Dict[str, int]:
        """GC entries and model artifacts for retired fleet members.

        Each ``keep_*`` is an iterable of values to KEEP for that key
        field (``None``: no constraint on that field); anything failing
        any given constraint is dropped.  Returns a stats dict —
        ``{"dropped_entries", "kept_entries", "dropped_models",
        "kept_models", "dropped"}`` — so a daemon's periodic GC can be
        logged and tested; with ``dry_run=True`` nothing is mutated (or
        saved), only the stats are computed.  Autosaves when bound to a
        path and something was actually dropped.

            store.prune(keep_hardware={"tpu_v5e"})   # tpu_v4 left the fleet
            store.prune(keep_kinds={"kernel"})       # drop serve/sharding
            store.prune(keep_spaces={"gemm"}, dry_run=True)   # would-drop
        """
        keep_hardware = set(keep_hardware) if keep_hardware is not None \
            else None
        keep_spaces = set(keep_spaces) if keep_spaces is not None else None
        keep_buckets = set(keep_buckets) if keep_buckets is not None \
            else None
        keep_kinds = set(keep_kinds) if keep_kinds is not None else None

        def drop(key: str) -> bool:
            kk, s, b, h = split_key(key)
            return ((keep_kinds is not None and kk not in keep_kinds)
                    or (keep_spaces is not None and s not in keep_spaces)
                    or (keep_buckets is not None and b not in keep_buckets)
                    or (keep_hardware is not None and h not in keep_hardware))

        def apply() -> Dict[str, int]:
            doomed_e = [k for k in self._entries if drop(k)]
            doomed_m = [k for k in self._models if drop(k)]
            if not dry_run:
                for k in doomed_e:
                    del self._entries[k]
                for k in doomed_m:
                    del self._models[k]
                    self._index_discard(k)
            return {
                "dropped_entries": len(doomed_e),
                "kept_entries": len(self._entries) - (len(doomed_e)
                                                      if dry_run else 0),
                "dropped_models": len(doomed_m),
                "kept_models": len(self._models) - (len(doomed_m)
                                                    if dry_run else 0),
                "dropped": len(doomed_e) + len(doomed_m),
            }

        stats = apply()
        if stats["dropped"] and not dry_run and self.path is not None \
                and self.autosave:
            # the on-disk copy still holds the pruned keys; a plain merging
            # save would adopt them straight back, so re-apply the filter
            # after the merge, inside the lock
            self.save(_post_merge=apply)
        return stats

    def _read_checked(self, path: str) -> Optional[Dict[str, Any]]:
        """Parse + checksum-verify a store file; quarantine on damage.

        Truncated/invalid JSON and checksum mismatches — the artifacts a
        crashed writer or bad disk leaves behind — move the file aside
        as ``<path>.corrupt`` and return None so the caller continues
        with what it has, instead of taking the whole load path down.
        A VALID file of the wrong format still raises: that is a caller
        pointing at the wrong file, not data damage.
        """
        try:
            with open(path) as f:
                d = json.load(f)
        except (json.JSONDecodeError, UnicodeDecodeError, OSError) as exc:
            self.quarantined.append(
                quarantine_file(path, f"unreadable store file: {exc}"))
            return None
        if not isinstance(d, dict):
            self.quarantined.append(
                quarantine_file(path, "store file is not a JSON object"))
            return None
        if d.get("format") != FORMAT:
            raise ValueError(
                f"not a {FORMAT} artifact: format={d.get('format')!r}")
        if d.get("version") not in READABLE_VERSIONS:
            raise ValueError(
                f"unsupported {FORMAT} version {d.get('version')!r}")
        crc = d.get("crc")
        if crc is not None and crc != content_crc(d.get("entries", {}),
                                                  d.get("models", {})):
            self.quarantined.append(
                quarantine_file(path, "content checksum mismatch"))
            return None
        return d

    def load(self, path: str) -> "ConfigStore":
        """Load a store file; a damaged one is quarantined and the store
        comes up EMPTY (but usable) rather than crashing the caller.
        Version-1 keys upgrade to the ``kind|...`` schema on load (the
        next save persists them in version-2 form)."""
        d = self._read_checked(path)
        if path == self.path:
            self._dirty_entries.clear()
            self._dirty_models.clear()
            self._disk_token = None    # not set race-free; next save reads
        if d is None:
            self._entries, self._models = {}, {}
            self._reindex_models()
            return self
        self._entries = {upgrade_key(k): StoreEntry.from_dict(e)
                         for k, e in d.get("entries", {}).items()}
        self._models = {}
        for k, m in d.get("models", {}).items():
            k = upgrade_key(k)
            # pre-v3 artifacts gain a signature on the way in; the next
            # save persists it (a version bump forces a full write)
            self._models[k] = ensure_signature(m, kind=split_key(k)[0])
        self._reindex_models()
        return self

    def _autosave(self) -> None:
        if self.path is not None and self.autosave:
            self.save()
