"""``ConfigStore`` — persistent tuned-config + model-artifact store.

The paper's motivation (ii): autotuning must be *repeated* whenever the
processed-data characteristics change, and a portable TP→PC model makes each
repetition cheap.  In a serving system that repetition happens online — the
request mix shifts, the engine retunes — so the results must outlive the
process: the second time a workload shape shows up (or the service restarts)
the tuned configuration is reused with ZERO live trials.

The store is one JSON file holding two artifact kinds under the same key
``(space name, input-shape bucket, hardware)``:

* **entries** — tuned configurations (`config`, `runtime`, `trials`, free-form
  `meta`), written by the online tuner after live trials;
* **models**  — trained TP→PC_ops model artifacts in the
  ``repro.tuning.serialize`` JSON format, so the warm-start ranking that
  keeps live-trial counts small is itself persistent and shippable across
  machines (``TuningSession.save_model_to_store``/``load_model_from_store``).

Schema (``format: repro.config_store``, version 1)::

    {
      "format": "repro.config_store",
      "version": 1,
      "entries": {
        "serve_online|p1n1|tpu_v5e": {
          "space": "serve_online", "bucket": "p1n1", "hardware": "tpu_v5e",
          "config": {"BATCH": 8, "MAX_SEQ": 64},
          "runtime": 0.0123,          # best measured seconds
          "trials": 6,                # live empirical tests spent tuning it
          "meta": {...}               # free-form (e.g. ask-tell history)
        }, ...
      },
      "models": { "<same key>": <repro.tppc_model artifact>, ... }
    }

Writes are atomic (tempfile + ``os.replace``) and auto-saved when the store
is bound to a path; ``ConfigStore()`` with no path is a process-local cache
with the same API.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from typing import Any, Dict, Iterator, Optional

from repro.core.model import TPPCModel
from repro.core.tuning_space import Config, TuningSpace
from repro.tuning.serialize import model_from_dict, model_to_dict

FORMAT = "repro.config_store"
VERSION = 1
_SEP = "|"


def store_key(space: str, bucket: str, hardware: str) -> str:
    """Canonical ``space|bucket|hardware`` key (fields must not contain |)."""
    parts = (str(space), str(bucket), str(hardware))
    for p in parts:
        if _SEP in p:
            raise ValueError(f"store key field {p!r} contains {_SEP!r}")
    return _SEP.join(parts)


@dataclasses.dataclass(frozen=True)
class StoreEntry:
    """One tuned configuration for one (space, bucket, hardware)."""

    space: str
    bucket: str
    hardware: str
    config: Config
    runtime: float              # best measured seconds at tuning time
    trials: int                 # live empirical tests spent finding it
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def key(self) -> str:
        return store_key(self.space, self.bucket, self.hardware)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "space": self.space, "bucket": self.bucket,
            "hardware": self.hardware, "config": dict(self.config),
            "runtime": float(self.runtime), "trials": int(self.trials),
            "meta": self.meta,
        }

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "StoreEntry":
        return StoreEntry(
            space=d["space"], bucket=d["bucket"], hardware=d["hardware"],
            config=dict(d["config"]), runtime=float(d["runtime"]),
            trials=int(d["trials"]), meta=dict(d.get("meta", {})),
        )


class ConfigStore:
    """JSON-backed artifact store for tuned configs and TP→PC models.

    ``path=None`` keeps everything in memory (same API, nothing persisted);
    with a path, the file is loaded if it exists and every ``put`` /
    ``put_model`` re-saves atomically.
    """

    def __init__(self, path: Optional[str] = None, autosave: bool = True):
        self.path = path
        self.autosave = autosave
        self._entries: Dict[str, StoreEntry] = {}
        self._models: Dict[str, Dict] = {}
        if path is not None and os.path.exists(path):
            self.load(path)

    # -- tuned configs ---------------------------------------------------------
    def get(self, space: str, bucket: str, hardware: str
            ) -> Optional[StoreEntry]:
        return self._entries.get(store_key(space, bucket, hardware))

    def put(self, space: str, bucket: str, hardware: str, config: Config,
            runtime: float, trials: int,
            meta: Optional[Dict[str, Any]] = None) -> StoreEntry:
        entry = StoreEntry(space=space, bucket=bucket, hardware=hardware,
                           config=dict(config), runtime=float(runtime),
                           trials=int(trials), meta=dict(meta or {}))
        self._entries[entry.key] = entry
        self._autosave()
        return entry

    def entries(self) -> Iterator[StoreEntry]:
        return iter(self._entries.values())

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: str) -> bool:
        return key in self._entries

    # -- model artifacts -------------------------------------------------------
    def get_model_dict(self, space: str, bucket: str, hardware: str
                       ) -> Optional[Dict]:
        return self._models.get(store_key(space, bucket, hardware))

    def put_model_dict(self, space: str, bucket: str, hardware: str,
                       artifact: Dict) -> None:
        self._models[store_key(space, bucket, hardware)] = artifact
        self._autosave()

    def load_model(self, space: str, bucket: str, hardware: str,
                   bind_space: Optional[TuningSpace] = None
                   ) -> Optional[TPPCModel]:
        """Reconstruct a stored model, optionally bound to an existing space
        (compatibility-checked by the serializer)."""
        d = self.get_model_dict(space, bucket, hardware)
        if d is None:
            return None
        return model_from_dict(d, space=bind_space)

    def save_model(self, space: str, bucket: str, hardware: str,
                   model: TPPCModel,
                   model_space: Optional[TuningSpace] = None) -> None:
        self.put_model_dict(space, bucket, hardware,
                            model_to_dict(model, model_space))

    # -- persistence -----------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return {
            "format": FORMAT,
            "version": VERSION,
            "entries": {k: e.to_dict() for k, e in
                        sorted(self._entries.items())},
            "models": {k: m for k, m in sorted(self._models.items())},
        }

    def save(self, path: Optional[str] = None) -> str:
        """Atomic write: serialize to a temp file, then ``os.replace``."""
        path = path if path is not None else self.path
        if path is None:
            raise ValueError("ConfigStore has no path; pass save(path=...)")
        d = os.path.dirname(os.path.abspath(path)) or "."
        fd, tmp = tempfile.mkstemp(prefix=".config_store.", dir=d)
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(self.to_dict(), f, indent=1)
            os.replace(tmp, path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        return path

    def load(self, path: str) -> "ConfigStore":
        with open(path) as f:
            d = json.load(f)
        if d.get("format") != FORMAT:
            raise ValueError(
                f"not a {FORMAT} artifact: format={d.get('format')!r}")
        if d.get("version") != VERSION:
            raise ValueError(
                f"unsupported {FORMAT} version {d.get('version')!r}")
        self._entries = {k: StoreEntry.from_dict(e)
                         for k, e in d.get("entries", {}).items()}
        self._models = dict(d.get("models", {}))
        return self

    def _autosave(self) -> None:
        if self.path is not None and self.autosave:
            self.save()
