"""``TuningProblem`` — one tuner-facing interface from kernel tiles to
whole-system spaces.

The paper's method is problem-agnostic: a tuning space, a portable
workload model ``g : TP × I → PC_ops`` whose counters feed the TP→PC
model, and (optionally) a measurement substrate for the hardware of
interest.  Historically "problem" meant "Pallas kernel" in this repo;
this module lifts the contract out so the SAME fleet, store, service
and searchers tune anything that speaks it:

* ``kernel`` — a thin adapter over ``kernels/registry.py`` (bit-identical
  to the legacy ``job_from_registry`` path, golden-gated);
* ``sharding`` — train-step sharding layouts for a model-zoo entry
  (mesh shape × ``ShardingRules`` variants), with roofline-style counters
  (FLOPs, HBM bytes, collective volume) as the profile features
  (``repro/distributed/tuning.py``);
* ``serve`` — serving wave geometry (batch size × cache length),
  wrapping ``serve/autotune.py``'s space + workload model
  (``ServeProblem`` in that module).

A problem also names its identity in the persistent ``ConfigStore``:
``kind`` is the key namespace (``kind|space|bucket|hardware``) and
``bucket`` the input-shape bucket, so artifacts from different problem
kinds never collide even when space names do.

The string registry (``register_problem_kind`` / ``make_problem`` /
``parse_problem``) is what the service protocol's ``problem`` submits
and the ``--problem kind:name`` CLI flags resolve through.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional

from repro.core.hwspec import HardwareSpec
from repro.core.tuning_space import Config, TuningSpace


class TuningProblem:
    """The tuner-facing contract every problem kind implements.

    Subclasses set class attribute ``kind`` (the store-key namespace and
    registry string) and instance attributes ``name`` (unique within the
    kind, e.g. ``"matmul/2048"`` or ``"qwen2.5-3b/train_4k"``) and
    ``bucket`` (the input-shape bucket the paper's ``I``), then implement:

    * ``space()`` — the ``TuningSpace`` to search;
    * ``workload_fn()`` — the portable counter model ``g(TP) → PC_ops``
      (hardware-independent; trains the TP→PC model and prices
      warm-start rankings);
    * ``make_evaluator(hw)`` — an optional measurement closure
      ``(index, profile) -> (runtime, counters, cost)`` for the hardware
      of interest.  ``None`` (the default) means "price ``workload_fn``
      through the analytic cost model" — the fleet's replay path, which
      keeps the kernel adapter bit-identical to the legacy traces.

    ``kernel``/``input_key`` are registry provenance for subprocess
    worker pools (which ship names, not closures); non-kernel problems
    leave them ``None`` and therefore need in-process pools.
    """

    kind: str = "problem"
    name: str = ""
    bucket: str = "default"
    kernel: Optional[str] = None
    input_key: Optional[str] = None

    def space(self) -> TuningSpace:
        raise NotImplementedError

    def workload_fn(self) -> Callable[[Config], Dict[str, float]]:
        raise NotImplementedError

    def make_evaluator(self, hw: HardwareSpec) -> Optional[Callable]:
        return None

    @property
    def spec(self) -> str:
        """The registry string that reconstructs this problem."""
        return f"{self.kind}:{self.name}"

    def describe(self) -> Dict[str, Any]:
        """Problem card for enumeration tools (``gen_experiments``)."""
        sp = self.space()
        return {
            "kind": self.kind,
            "name": self.name,
            "bucket": self.bucket,
            "space": sp.name,
            "n_configs": len(sp),
            "parameters": {p.name: list(p.values) for p in sp.parameters},
        }

    def __repr__(self) -> str:
        return f"{type(self).__name__}({self.spec!r})"


# =============================================================================
# The string-keyed registry
# =============================================================================
_FACTORIES: Dict[str, Callable[..., TuningProblem]] = {}
_LISTERS: Dict[str, Callable[[], List[str]]] = {}


def register_problem_kind(kind: str,
                          lister: Optional[Callable[[], List[str]]] = None):
    """Register a factory ``f(name, **params) -> TuningProblem`` for
    ``kind`` (decorator).  ``lister`` optionally enumerates example
    problem names of the kind for discovery tools."""
    def deco(factory):
        _FACTORIES[kind] = factory
        if lister is not None:
            _LISTERS[kind] = lister
        return factory
    return deco


def problem_kinds() -> List[str]:
    """All registered problem kinds, sorted."""
    return sorted(_FACTORIES)


def make_problem(kind: str, name: str, **params: Any) -> TuningProblem:
    """Instantiate a registered problem kind by name."""
    if kind not in _FACTORIES:
        raise KeyError(
            f"unknown problem kind {kind!r}; valid kinds: "
            f"{', '.join(problem_kinds())}")
    return _FACTORIES[kind](name, **params)


def parse_problem(spec: str, **params: Any) -> TuningProblem:
    """Resolve a ``kind:name`` spec (the CLI/service form) to a problem."""
    kind, sep, name = spec.partition(":")
    if not sep or not kind or not name:
        raise ValueError(
            f"problem spec must be 'kind:name', got {spec!r}; valid "
            f"kinds: {', '.join(problem_kinds())}")
    return make_problem(kind, name, **params)


def list_problems(kind: Optional[str] = None) -> List[str]:
    """Example ``kind:name`` specs across registered kinds (or one kind)."""
    kinds = [kind] if kind is not None else problem_kinds()
    out: List[str] = []
    for k in kinds:
        lister = _LISTERS.get(k)
        if lister is not None:
            out.extend(f"{k}:{n}" for n in lister())
    return out


# =============================================================================
# kind = "kernel" — the registry adapter (bit-identical to the legacy path)
# =============================================================================
class KernelProblem(TuningProblem):
    """A registered Pallas kernel benchmark on one named input.

    ``make_evaluator`` returns ``None`` on purpose: the fleet then prices
    the workload through the analytic cost model exactly as the legacy
    ``job_from_registry`` jobs did, so ask-tell traces stay bit-identical
    (the golden gate in ``tests/test_problems.py``).
    """

    kind = "kernel"

    def __init__(self, kernel: str, input_key: Optional[str] = None):
        from repro.kernels.registry import BENCHMARKS
        if kernel not in BENCHMARKS:
            raise KeyError(f"unknown kernel {kernel!r}; available: "
                           f"{sorted(BENCHMARKS)}")
        bm = BENCHMARKS[kernel]
        if input_key is None:
            input_key = sorted(bm.inputs)[0]
        if input_key not in bm.inputs:
            raise KeyError(f"kernel {kernel!r} has no input {input_key!r}; "
                           f"available: {sorted(bm.inputs)}")
        self._bm = bm
        self.kernel = kernel
        self.input_key = input_key
        self.name = f"{kernel}/{input_key}"
        self.bucket = input_key

    def space(self) -> TuningSpace:
        return self._bm.space()

    def workload_fn(self) -> Callable[[Config], Dict[str, float]]:
        bm, inp = self._bm, self._bm.inputs[self.input_key]
        return lambda cfg: bm.workload_fn(cfg, inp)


def _kernel_names() -> List[str]:
    from repro.kernels.registry import BENCHMARKS
    return [f"{k}/{i}" for k in sorted(BENCHMARKS)
            for i in sorted(BENCHMARKS[k].inputs)]


@register_problem_kind("kernel", lister=_kernel_names)
def _make_kernel(name: str, **params: Any) -> KernelProblem:
    kernel, _, input_key = name.partition("/")
    return KernelProblem(kernel, input_key or None, **params)


# =============================================================================
# kind = "sharding" / "serve" — lazy factories (heavy imports on demand)
# =============================================================================
def _sharding_names() -> List[str]:
    from repro.configs import ARCHS
    return [f"{a}/train_4k" for a in sorted(ARCHS)]


@register_problem_kind("sharding", lister=_sharding_names)
def _make_sharding(name: str, **params: Any) -> TuningProblem:
    from repro.distributed.tuning import ShardingProblem
    return ShardingProblem.from_name(name, **params)


def _serve_names() -> List[str]:
    return ["p9n9", "p4n4", "p9n0"]


@register_problem_kind("serve", lister=_serve_names)
def _make_serve(name: str, **params: Any) -> TuningProblem:
    from repro.serve.autotune import ServeProblem
    return ServeProblem.from_name(name, **params)


# =============================================================================
# Whole-system convenience: every problem kind for one model-zoo entry
# =============================================================================
def system_problems(arch: str, shape: str = "train_4k",
                    n_devices: int = 64,
                    kernels: Optional[List[str]] = None
                    ) -> List[TuningProblem]:
    """Kernel tiles + train-step sharding + serve geometry for one
    model-zoo entry — the one-invocation ``launch/fleet.py --system``
    mode tunes exactly this list through one fleet and one store."""
    from repro.distributed.tuning import ShardingProblem
    from repro.serve.autotune import ServeProblem

    problems: List[TuningProblem] = []
    from repro.kernels.registry import BENCHMARKS
    for k in (kernels if kernels is not None else sorted(BENCHMARKS)):
        problems.append(KernelProblem(k))
    problems.append(ShardingProblem.from_name(f"{arch}/{shape}",
                                              n_devices=n_devices))
    problems.append(ServeProblem.from_name("p9n9", arch=arch))
    return problems
