"""Trip-count-aware HLO cost parser — the dry-run "performance counters".

XLA's ``cost_analysis()`` counts while-loop bodies ONCE, so scan-over-layers
programs under-report flops/bytes/collectives by the trip count.  This parser
walks the post-optimization HLO text, attributes every op to its computation,
resolves while-loop trip counts from their condition computations, and
accumulates flops / bytes / per-collective bytes with loop multipliers —
yielding the execution totals of one program run on one device.

This module is the TPU analog of the paper's counter collection: PC_ops
extracted statically from the compiled artifact (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "s8": 1, "u2": 1, "u4": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_ARR = re.compile(r"(\w+)\[([\d,]*)\]")
# op shape may be a tuple containing /*index=N*/ comments (scheduled HLO)
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(.*?\)|[\w\[\],{}\s]+?)\s*"
    r"([\w\-]+)\((.*)$"
)
_COMP_HEADER = re.compile(
    r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\s*\{\s*$")
_CALLED = re.compile(
    r"(?:calls|condition|body|to_apply|true_computation|false_computation"
    r"|branch_computations)=\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")
_CONST_S32 = re.compile(r"s32\[\]\s+constant\((\d+)\)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _arrays_bytes(shape_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_ARR.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_dim_product(shape_str: str) -> int:
    m = _SHAPE_ARR.search(shape_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    out_shape: str
    rest: str
    called: List[str]
    is_root: bool = False


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        header = _COMP_HEADER.match(line.strip())
        if header and ("=" not in line.split("(")[0]):
            name = header.group(1)
            cur = Computation(name=name, ops=[])
            comps[name] = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_LINE.match(line)
        if not m:
            continue
        name, shape, kind, rest = m.groups()
        called = []
        for cm in _CALLED.finditer(line):
            for c in cm.group(1).split(","):
                called.append(c.strip().lstrip("%"))
        cur.ops.append(Op(name=name, kind=kind, out_shape=shape.strip(),
                          rest=rest, called=called,
                          is_root=line.lstrip().startswith("ROOT")))
    return comps


def _find_entry(comps: Dict[str, Computation], hlo: str) -> str:
    m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo)
    if m and m.group(1) in comps:
        return m.group(1)
    # fallback: computation that is never called
    called = set()
    for c in comps.values():
        for op in c.ops:
            called.update(op.called)
    for name in comps:
        if name not in called:
            return name
    return next(iter(comps))


def _trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    """Largest s32 constant in the condition computation (or its callees)."""
    best = 1
    seen = set()
    stack = [cond_name]
    while stack:
        name = stack.pop()
        if name in seen or name not in comps:
            continue
        seen.add(name)
        for op in comps[name].ops:
            for m in _CONST_S32.finditer(op.rest):
                best = max(best, int(m.group(1)))
            m2 = _CONST_S32.search(op.out_shape + " " + op.kind)
            if op.kind == "constant":
                m3 = re.search(r"constant\((\d+)\)", op.kind + "(" + op.rest)
                if m3:
                    best = max(best, int(m3.group(1)))
            stack.extend(op.called)
    return best


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    collective_counts: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "HloCost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.transcendentals += other.transcendentals * mult
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] = self.collective_bytes.get(k, 0.0) \
                + v * mult
        for k, v in other.collective_counts.items():
            self.collective_counts[k] = self.collective_counts.get(k, 0.0) \
                + v * mult

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


_TRANS_KINDS = {"exponential", "log", "rsqrt", "sqrt", "tanh", "power",
                "logistic", "exponential-minus-one", "log-plus-one", "cosine",
                "sine"}

# Ops whose operands/outputs stream through HBM on TPU (fusion boundaries
# and explicit data movement); everything else is assumed fused.
_BYTES_KINDS = {
    "fusion", "dot", "convolution", "dynamic-slice", "dynamic-update-slice",
    "gather", "scatter", "reduce", "reduce-window", "sort", "custom-call",
    "concatenate", "pad", "cholesky", "triangular-solve", "fft", "rng",
}


def _dot_flops(op: Op, defs: Dict[str, str]) -> float:
    """2 × |out| × contracted extent (per batch already in |out|)."""
    out_elems = _first_dim_product(op.out_shape)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    operands = [o.strip().lstrip("%") for o in
                re.findall(r"%([\w.\-]+)", op.rest.split(")", 1)[0])]
    k = 1
    if m and operands:
        lhs_shape = defs.get(operands[0], "")
        sm = _SHAPE_ARR.search(lhs_shape)
        if sm:
            dims = [int(d) for d in sm.group(2).split(",") if d]
            for ci in m.group(1).split(","):
                if ci and int(ci) < len(dims):
                    k *= dims[int(ci)]
    return 2.0 * out_elems * k


def _inplace_update_bytes(op: Op, comps: Dict[str, "Computation"],
                          defs: Dict[str, str], operand_names: List[str]
                          ) -> Optional[float]:
    """For dynamic-update-slice (or a fusion rooted in one): 2 × update size.

    XLA performs these in place (donated/aliased buffers), so the HBM
    traffic is the written region plus the update read — not the full
    buffer copy the functional HLO suggests.
    """
    update_shape = None
    if op.kind == "dynamic-update-slice":
        if len(operand_names) >= 2:
            update_shape = defs.get(operand_names[1], "")
    elif op.kind == "fusion" and op.called:
        # a DUS anywhere in the fusion whose dims equal the fusion output is
        # an in-place buffer update; CPU bf16 legalization wraps it in
        # convert ops (f32 round trip) that a TPU compile would not have
        comp = comps.get(op.called[0])
        out_dims = _SHAPE_ARR.search(op.out_shape)
        if comp and out_dims:
            for o in comp.ops:
                if o.kind != "dynamic-update-slice":
                    continue
                od = _SHAPE_ARR.search(o.out_shape)
                if od and od.group(2) == out_dims.group(2):
                    args = o.rest.split(")", 1)[0]
                    inner_ops = re.findall(r"%([\w.\-]+)", args)
                    if len(inner_ops) >= 2:
                        update_shape = defs.get(inner_ops[1], "")
                    break
    if update_shape is None:
        return None
    return 2.0 * _arrays_bytes(update_shape)


def analyze(hlo: str) -> HloCost:
    comps = parse_computations(hlo)
    entry = _find_entry(comps, hlo)
    # map op name -> out shape (for operand shape resolution), global
    defs: Dict[str, str] = {}
    for c in comps.values():
        for op in c.ops:
            defs[op.name] = op.out_shape

    memo: Dict[Tuple[str, bool], HloCost] = {}

    def cost_of(comp_name: str, count_bytes: bool = True) -> HloCost:
        """Accumulate cost of one computation.

        ``count_bytes=False`` inside fusion-called computations: their
        internal ops live in registers/VMEM — only the fusion's boundary
        I/O (counted at the fusion op site) touches memory.
        """
        key = (comp_name, count_bytes)
        if key in memo:
            return memo[key]
        memo[key] = HloCost()  # break cycles defensively
        total = HloCost()
        comp = comps.get(comp_name)
        if comp is None:
            return total
        for op in comp.ops:
            if op.kind == "while":
                mb = re.search(r"body=%?([\w.\-]+)", op.rest)
                mc = re.search(r"condition=%?([\w.\-]+)", op.rest)
                trips = _trip_count(comps, mc.group(1)) if mc else 1
                if mb:
                    total.add(cost_of(mb.group(1), count_bytes), mult=trips)
                continue
            if op.kind in ("call", "conditional", "async-start"):
                for c in op.called:
                    total.add(cost_of(c, count_bytes))
            elif op.kind in ("fusion", "map", "reduce", "reduce-window",
                             "scatter", "sort", "select-and-scatter",
                             "custom-call"):
                for c in op.called:
                    total.add(cost_of(c, False))
            base = op.kind.replace("-start", "")
            if op.kind.endswith("-done"):
                continue
            if base in COLLECTIVES:
                b = _arrays_bytes(op.out_shape)
                total.collective_bytes[base] = \
                    total.collective_bytes.get(base, 0.0) + b
                total.collective_counts[base] = \
                    total.collective_counts.get(base, 0.0) + 1
                if count_bytes:
                    total.bytes += 2 * b
                continue
            if op.kind == "dot":
                total.flops += _dot_flops(op, defs)
            elif op.kind == "convolution":
                total.flops += 2.0 * _first_dim_product(op.out_shape)
            elif op.kind in _TRANS_KINDS:
                total.transcendentals += _first_dim_product(op.out_shape)
                total.flops += _first_dim_product(op.out_shape)
            elif op.kind in ("add", "multiply", "subtract", "divide",
                             "maximum", "minimum", "compare", "select",
                             "and", "or", "xor", "negate", "abs", "floor",
                             "ceil", "round-nearest-afz", "clamp"):
                total.flops += _first_dim_product(op.out_shape)
            # bytes: output write + operand reads (resolved from defs).
            # Only ops that are HBM-level on TPU count: fusion boundaries,
            # dots, explicit data movement.  Standalone elementwise/layout
            # ops (convert/copy/broadcast/transpose/...) are CPU-HLO
            # artifacts that the TPU compiler fuses away.
            if not count_bytes or op.kind not in _BYTES_KINDS:
                continue
            args = op.rest.split(")", 1)[0]
            operand_names = re.findall(r"%([\w.\-]+)", args)
            # in-place update ops (scan carries, KV-cache writes): traffic is
            # the updated region, not the whole buffer (XLA aliases these)
            dus_bytes = _inplace_update_bytes(op, comps, defs, operand_names)
            if dus_bytes is not None:
                total.bytes += dus_bytes
                continue
            if op.kind == "dynamic-slice":
                total.bytes += 2 * _arrays_bytes(op.out_shape)
                continue
            b_out = _arrays_bytes(op.out_shape)
            b_in = sum(_arrays_bytes(defs.get(o, "")) for o in operand_names
                       if o in defs)
            total.bytes += b_out + b_in
        memo[key] = total
        return total

    return cost_of(entry)
