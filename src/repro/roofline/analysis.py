"""Three-term roofline from a compiled dry-run artifact.

    compute    = HLO_FLOPs / (chips × peak)
    memory     = HLO_bytes / (chips × HBM_bw)
    collective = Σ per-chip collective bytes × ring factor / link_bw_per_chip

``cost_analysis`` provides flops/bytes.  Collective bytes are NOT in
cost_analysis: we parse the post-SPMD HLO (``compiled.as_text()``) and sum
operand sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops.  Shapes in post-SPMD HLO are per-participant shard
shapes; ring factors: AG/RS move (n-1)/n · full bytes per chip, AR = 2·(n-1)/n,
A2A = (n-1)/n, permute = 1.  Effective per-chip collective bandwidth on a 2D
torus: links_per_axis(2) × link_bw for ring collectives along one mesh axis.

Hardware constants per the assignment: 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (v5e).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

# v5e per-chip constants (assignment-specified)
PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_LINK_BW = 50e9
ICI_LINKS_PER_COLLECTIVE = 2   # ring over one torus axis uses 2 links/chip
DCN_BW = 6.25e9                # cross-pod per-chip share

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVE_RE = re.compile(
    r"(?P<shape>[\w\[\]{,\s]*?)\s*"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?"
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_RING_FACTOR = {
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-reduce": 2.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_op: Dict[str, float]
    count_by_op: Dict[str, int]

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_op.values())


def _shape_bytes(shape_str: str) -> float:
    """Sum byte sizes of all arrays in an HLO shape string (incl. tuples)."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum per-participant operand bytes of every collective op."""
    bytes_by_op: Dict[str, float] = {}
    count_by_op: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.search(
            r"=\s*([\w\[\],{}\s]*?)\s*"
            r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
            r"collective-permute)(-start)?\(", line)
        if not m:
            continue
        op = m.group(2)
        # skip the matching -done ops (bytes counted at -start)
        out_shape = m.group(1)
        b = _shape_bytes(out_shape)
        if b == 0.0:
            continue
        bytes_by_op[op] = bytes_by_op.get(op, 0.0) + b
        count_by_op[op] = count_by_op.get(op, 0) + 1
    return CollectiveStats(bytes_by_op, count_by_op)


@dataclasses.dataclass
class Roofline:
    flops: float               # total HLO flops (whole program, all chips)
    hbm_bytes: float           # total bytes accessed
    collective_bytes: float    # per-chip collective bytes (ring-scaled)
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    collectives: CollectiveStats
    model_flops: float = 0.0   # 6·N·D (or 6·N_active·D) useful flops
    xla_reported_flops: float = 0.0  # raw cost_analysis (loop bodies x1)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    def summary(self) -> Dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "xla_reported_flops": self.xla_reported_flops,
            "collective_by_op": self.collectives.bytes_by_op,
            "collective_counts": self.collectives.count_by_op,
        }


def analyze_compiled(
    compiled, chips: int, model_flops: float = 0.0,
    hlo_text: Optional[str] = None,
) -> Roofline:
    """Roofline from the compiled artifact.

    Flops/bytes/collectives come from the trip-count-aware HLO parser
    (hlo_parse.py): XLA's own ``cost_analysis()`` counts while bodies once,
    so scan-over-layers programs under-report by the trip count.  Parsed
    numbers are PER-DEVICE (post-SPMD shard shapes) per program execution.
    ``cost_analysis`` is kept in the record as a cross-check.
    """
    from repro.roofline import hlo_parse
    text = hlo_text if hlo_text is not None else compiled.as_text()
    parsed = hlo_parse.analyze(text)
    flops = parsed.flops
    hbm = parsed.bytes
    coll = CollectiveStats(
        bytes_by_op=dict(parsed.collective_bytes),
        count_by_op={k: int(v) for k, v in parsed.collective_counts.items()},
    )
    per_chip_coll = sum(
        b * _RING_FACTOR.get(op, 1.0) for op, b in coll.bytes_by_op.items())
    ici_bw = ICI_LINK_BW * ICI_LINKS_PER_COLLECTIVE
    try:
        xla_cost = compiled.cost_analysis()
        if isinstance(xla_cost, list):
            xla_cost = xla_cost[0]
        xla_flops = float(xla_cost.get("flops", 0.0))
    except Exception:  # noqa: BLE001
        xla_flops = 0.0
    return Roofline(
        flops=flops * chips,          # global logical flops
        hbm_bytes=hbm * chips,
        collective_bytes=per_chip_coll,
        chips=chips,
        compute_s=flops / PEAK_FLOPS,
        memory_s=hbm / HBM_BW,
        collective_s=per_chip_coll / ici_bw,
        collectives=coll,
        model_flops=model_flops,
        xla_reported_flops=xla_flops,
    )


def model_flops_train(n_params_active: int, tokens: int) -> float:
    return 6.0 * n_params_active * tokens


def model_flops_decode(n_params_active: int, tokens: int) -> float:
    return 2.0 * n_params_active * tokens
