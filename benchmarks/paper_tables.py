"""Reproduction of the paper's tables/figures on the TPU-adapted testbed.

Table 4  — random-search steps per benchmark × hardware
Table 5  — profile-searcher improvement, exact PCs, same hardware
Table 6  — hardware-portability matrices (tree model from hw A, tune on B)
Table 7  — GEMM input-portability matrix
Figs 3-8 — convergence-in-time (incl. profiling overhead + GEMM-full)
Table 8  — Starchart (model build + tuning) vs random
Table 9  — Starchart@A-model vs proposed@A-model, tuning on B

The paper's 4 GPUs map to 4 virtual TPUs (hwspec.PORTABILITY_SET); recorded
spaces come from the analytic execution model over statically-derived kernel
counters (DESIGN.md §2) and are replayed exactly as the paper replays its
recorded spaces (§4.1).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import numpy as np

from repro.core import (SPECS, ReplayEvaluator, convergence_curve,
                        record_space, run_search_experiment,
                        steps_to_well_performing, train_model)
from repro.core.evaluate import RecordedSpace
from repro.kernels.registry import BENCHMARKS, GEMM_FULL_SPACE
from repro.tuning import SEARCHERS, make_searcher, run_search


def _searcher_factory(name: str, space, **context):
    """seed -> searcher, via the uniform registry construction."""
    return lambda s: make_searcher(name, space, seed=s, **context)

HWS = ("tpu_v4", "tpu_v5e", "tpu_v5p", "tpu_v6e")
PAPER_BENCH = ("coulomb", "transpose", "matmul", "nbody", "conv2d")
LABEL = {"coulomb": "Coulomb sum", "transpose": "Matrix trans.",
         "matmul": "GEMM", "nbody": "n-body", "conv2d": "Convolution",
         "attention": "FlashAttention"}


@functools.lru_cache(maxsize=None)
def recorded(bench: str, hw: str, input_key: Optional[str] = None
             ) -> RecordedSpace:
    bm = BENCHMARKS[bench]
    inp = bm.inputs[input_key] if input_key else bm.default_input
    if bench == "matmul" and input_key is not None:
        sp = bm.make_space(inp)   # expert input-aware pruning (§4.2)
    else:
        sp = bm.make_space()
    return record_space(sp, lambda c: bm.workload_fn(c, inp), SPECS[hw],
                        input_tag=getattr(inp, "tag", str(input_key)))


@functools.lru_cache(maxsize=None)
def recorded_gemm_full(hw: str) -> RecordedSpace:
    bm = BENCHMARKS["matmul"]
    sp = GEMM_FULL_SPACE()
    return record_space(sp, lambda c: bm.workload_fn(c, bm.default_input),
                        SPECS[hw])


@functools.lru_cache(maxsize=None)
def _tree_model_pre(bench: str, model_hw: str, tune_hw: str,
                    input_key: Optional[str] = None,
                    model_input: Optional[str] = None):
    # no precompute wrapper needed: the searchers score against the
    # model-keyed prediction matrix (repro.core.model.prediction_matrix),
    # which is materialized once and shared across all repetitions
    return train_model(recorded(bench, model_hw, model_input or input_key),
                       kind="tree")


def _fmt_row(name, cells, w=14):
    return f"{name:16s}" + "".join(f"{c:>{w}}" for c in cells)


# =============================================================================
def table4_random_steps(reps: int = 200):
    print("\n## Table 4 — mean empirical tests for RANDOM search to find a "
          "well-performing configuration")
    print(_fmt_row("benchmark", HWS))
    rows = {}
    for bench in PAPER_BENCH + ("attention",):
        cells = []
        for hw in HWS:
            rec = recorded(bench, hw)
            st = run_search_experiment(
                _searcher_factory("random", rec.space), rec, reps)
            rows[(bench, hw)] = st.mean_steps
            cells.append(f"{st.mean_steps:.1f}")
        print(_fmt_row(LABEL[bench], cells))
    return rows


def table5_profile_vs_random(reps: int = 200, t4=None):
    print("\n## Table 5 — improvement of the profile-based searcher over "
          "random (exact PCs, same hardware)")
    print(_fmt_row("benchmark", HWS))
    t4 = t4 or {}
    for bench in PAPER_BENCH + ("attention",):
        cells = []
        for hw in HWS:
            rec = recorded(bench, hw)
            model = train_model(rec, kind="exact")
            st_p = run_search_experiment(
                _searcher_factory("profile", rec.space, model=model,
                                  cores=SPECS[hw].cores),
                rec, reps)
            base = t4.get((bench, hw))
            if base is None:
                base = run_search_experiment(
                    _searcher_factory("random", rec.space),
                    rec, reps).mean_steps
            cells.append(f"{base / st_p.mean_steps:.2f}x")
        print(_fmt_row(LABEL[bench], cells))


def table6_hw_portability(reps: int = 150):
    print("\n## Table 6 — hardware portability: tree model from column-HW, "
          "autotuning on row-HW (improvement over random)")
    for bench in PAPER_BENCH:
        print(f"\n### {LABEL[bench]}")
        print(_fmt_row("tune \\ model", HWS))
        base = {}
        for hw in HWS:
            rec = recorded(bench, hw)
            base[hw] = run_search_experiment(
                _searcher_factory("random", rec.space),
                rec, reps).mean_steps
        for tune_hw in HWS:
            rec = recorded(bench, tune_hw)
            cells = []
            for model_hw in HWS:
                model = _tree_model_pre(bench, model_hw, tune_hw)
                st = run_search_experiment(
                    _searcher_factory("profile", rec.space, model=model,
                                      cores=SPECS[tune_hw].cores),
                    rec, reps)
                cells.append(f"{base[tune_hw] / st.mean_steps:.2f}x")
            print(_fmt_row(tune_hw, cells))


def table7_input_portability(reps: int = 150):
    inputs = ("2048", "128", "16x4096", "4096x16")
    print("\n## Table 7 — GEMM input portability on tpu_v5e: model from "
          "column-input, autotuning on row-input (improvement over random)")
    print(_fmt_row("tune \\ model", inputs))
    for tune_in in inputs:
        rec = recorded("matmul", "tpu_v5e", tune_in)
        base = run_search_experiment(
            _searcher_factory("random", rec.space), rec, reps).mean_steps
        cells = []
        for model_in in inputs:
            model = _tree_model_pre("matmul", "tpu_v5e", "tpu_v5e",
                                    input_key=tune_in, model_input=model_in)
            st = run_search_experiment(
                _searcher_factory("profile", rec.space, model=model,
                                  cores=SPECS["tpu_v5e"].cores),
                rec, reps)
            cells.append(f"{base / st.mean_steps:.2f}x")
        print(_fmt_row(tune_in, cells))


def fig_convergence(reps: int = 60):
    """Figs 3-8: wall-clock convergence — profiled steps cost extra time.

    Model built on tpu_v4 (the 'older GPU'), tuning on tpu_v5e (the 'brand
    new' one) — the paper's §4.6 scenario.
    """
    print("\n## Figs 3-8 — convergence in (simulated) tuning wall-clock, "
          "model from tpu_v4, tuning on tpu_v5e")
    print(f"{'benchmark':16s}{'searcher':10s}" + "".join(
        f"  t={t:>4.0f}s" for t in (2, 5, 10, 20, 40)))
    for bench in ("matmul", "conv2d", "nbody", "coulomb", "transpose"):
        rec = recorded(bench, "tpu_v5e")
        model = _tree_model_pre(bench, "tpu_v4", "tpu_v5e")
        for label, factory in (
            ("profile", _searcher_factory("profile", rec.space, model=model,
                                          cores=SPECS["tpu_v5e"].cores)),
            ("random", _searcher_factory("random", rec.space)),
        ):
            grid = np.array([2.0, 5.0, 10.0, 20.0, 40.0])
            _, mean, _ = convergence_curve(factory, rec, repeats=reps,
                                           time_grid=grid)
            print(f"{LABEL[bench]:16s}{label:10s}" + "".join(
                f"  {m * 1e3:6.2f}" for m in mean) + "   [ms best-so-far]")

    # Fig. 8 analog: GEMM-full searched with the model from the REDUCED
    # GEMM space (<3% of configurations, fewer dims)
    rec_full = recorded_gemm_full("tpu_v5e")
    model_small = train_model(recorded("matmul", "tpu_v4"), kind="tree")
    grid = np.array([5.0, 10.0, 20.0, 40.0, 80.0])
    for label, factory in (
        ("profile", _searcher_factory("profile", rec_full.space,
                                      model=model_small,
                                      cores=SPECS["tpu_v5e"].cores)),
        ("random", _searcher_factory("random", rec_full.space)),
    ):
        _, mean, _ = convergence_curve(factory, rec_full,
                                       repeats=max(reps // 3, 10),
                                       time_grid=grid)
        print(f"{'GEMM-full':16s}{label:10s}" + "".join(
            f"  {m * 1e3:6.2f}" for m in mean) + "   [ms best-so-far]")


def table8_starchart(reps: int = 40):
    print("\n## Table 8 — Starchart vs random (tpu_v5e): empirical steps")
    print(_fmt_row("benchmark", ("model build", "tuning", "random")))
    for bench in PAPER_BENCH:
        rec = recorded(bench, "tpu_v5e")
        builds, tunes = [], []
        thresh = rec.best_runtime * 1.1
        for rep in range(reps):
            s = SEARCHERS["starchart"](rec.space, seed=rep)
            ev = ReplayEvaluator(rec)
            run_search(s, ev, max_steps=len(rec.space))
            steps, _ = steps_to_well_performing(ev, thresh)
            builds.append(s.model_build_steps)
            tunes.append(max(0, (steps or ev.steps) - s.model_build_steps))
        rand = run_search_experiment(
            _searcher_factory("random", rec.space), rec, reps)
        print(_fmt_row(LABEL[bench], (
            f"{np.mean(builds):.0f}", f"{np.mean(tunes):.0f}",
            f"{rand.mean_steps:.0f}")))


def table9_cross_hw_starchart(reps: int = 40):
    print("\n## Table 9 — models from tpu_v4, tuning on tpu_v5e: "
          "Starchart tree vs proposed searcher (steps after model build)")
    print(_fmt_row("benchmark", ("SC@v4", "proposed@v4")))
    for bench in PAPER_BENCH:
        rec_b = recorded(bench, "tpu_v5e")
        rec_a = recorded(bench, "tpu_v4")
        thresh = rec_b.best_runtime * 1.1
        # Starchart: train runtime tree on hw A, walk predictions on hw B
        from repro.core.model import _build_tree, _tree_predict_batch
        X = rec_a.space.feature_matrix
        sc_steps = []
        for rep in range(reps):
            rngl = np.random.default_rng(rep)
            idx = rngl.permutation(len(rec_a.space))[:200]
            tree = _build_tree(X[idx], rec_a.runtimes[idx], 0, 12, 1)
            order = np.argsort(_tree_predict_batch(tree, X))
            ev = ReplayEvaluator(rec_b)
            for i in order:
                ev.measure(int(i))
                s, _ = steps_to_well_performing(ev, thresh)
                if s is not None:
                    break
            sc_steps.append(ev.steps)
        model = train_model(rec_a, kind="tree")
        st_p = run_search_experiment(
            _searcher_factory("profile", rec_b.space, model=model,
                              cores=SPECS["tpu_v5e"].cores),
            rec_b, reps)
        print(_fmt_row(LABEL[bench], (
            f"{np.mean(sc_steps):.0f}", f"{st_p.mean_steps:.0f}")))


def table_basin_hopping(reps: int = 60):
    print("\n## §4.7 analog — Basin Hopping vs random vs proposed "
          "(steps to well-performing, tpu_v5e, model from tpu_v4)")
    print(_fmt_row("benchmark",
                   ("random", "basin-hop", "proposed", "prop+local")))
    for bench in PAPER_BENCH:
        rec = recorded(bench, "tpu_v5e")
        model = _tree_model_pre(bench, "tpu_v4", "tpu_v5e")
        st_r = run_search_experiment(
            _searcher_factory("random", rec.space), rec, reps)
        st_b = run_search_experiment(
            _searcher_factory("basin_hopping", rec.space), rec, reps)
        st_p = run_search_experiment(
            _searcher_factory("profile", rec.space, model=model,
                              cores=SPECS["tpu_v5e"].cores),
            rec, reps)
        st_l = run_search_experiment(
            _searcher_factory("profile_local", rec.space, model=model,
                              cores=SPECS["tpu_v5e"].cores),
            rec, reps)
        print(_fmt_row(LABEL[bench], (
            f"{st_r.mean_steps:.0f}", f"{st_b.mean_steps:.0f}",
            f"{st_p.mean_steps:.0f}", f"{st_l.mean_steps:.0f}")))


def session_portability_demo(budget: int = 25):
    """The public-API flow end-to-end: train a model on tpu_v4, serialize it
    to JSON, load it into a fresh session and tune every benchmark on
    tpu_v5e — the paper's headline portability as an actual artifact."""
    import os
    import tempfile

    from repro.tuning import TuningSession

    print("\n## TuningSession — portable-model artifact demo "
          "(train tpu_v4 → JSON → tune tpu_v5e)")
    print(_fmt_row("benchmark", ("space", "artifact", "steps", "vs best")))
    for bench in PAPER_BENCH:
        bm = BENCHMARKS[bench]
        sp = bm.make_space()
        wl = lambda c: bm.workload_fn(c, bm.default_input)
        trainer = TuningSession(sp, wl, hw=SPECS["tpu_v4"], seed=0)
        trainer.train()
        with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as f:
            path = f.name
        try:
            trainer.save_model(path)
            size = os.path.getsize(path)
            tuner = TuningSession(sp, wl, hw=SPECS["tpu_v5e"], seed=1)
            tuner.load_model(path)
            res = tuner.tune(budget=budget)
        finally:
            os.unlink(path)
        best = recorded(bench, "tpu_v5e").best_runtime
        print(_fmt_row(LABEL[bench], (
            f"{len(sp)}", f"{size/1024:.1f}KB", f"{res.steps}",
            f"{res.best_runtime / best:.2f}x")))
