"""Fleet orchestration benchmark: wall-clock speedup + warm-start savings.

Two experiments on the deterministic synthetic backend (registry kernel
workloads priced through the cost model, scheduled on a virtual clock — so
every number is bit-reproducible):

1. **Speedup** — the same six cold tuning jobs (3 kernels × 2 hardware
   targets, fixed random-search trial budgets, identical work by
   construction) run sequentially (1 worker, ``in_flight=1``) and as a
   fleet (``--workers`` workers, ``in_flight=--workers``); the ratio of
   simulated wall-clocks is the orchestration speedup.  Target: ≥ 3× at 4
   workers.  ``--threads`` additionally replays the fleet on the real
   ``ThreadWorkerPool`` (measurement callables sleep their simulated cost)
   to show the same speedup on honest wall time.

2. **Warm start** — a fresh shared ``ConfigStore``: wave 1 tunes 3 kernels
   cold on the first hardware (training + publishing portable TP→PC_ops
   artifacts on completion), wave 2 tunes the same kernels on the second
   hardware, warm-starting from the nearest stored artifact.  Convergence
   = completed trials until within 1.1× of that (kernel, hardware)'s
   exhaustive best (the paper's well-performing criterion).  Target:
   warm-started jobs converge in ≤ half the trials of cold jobs (mean).

3. **Fault injection** — the same cold fleet under deterministic faults:
   1 of 4 lanes is killed mid-run and 10% of empirical tests fail
   (seeded), exercising the retry/known-bad/abandoned-accounting paths.
   Gates: every job still resolves its full budget (nothing silently
   dropped), no test needed more than 2 retries, the abandoned
   worker-seconds are charged into ``busy``, and the faulted fleet still
   beats the fault-free sequential baseline ≥ 2× wall-clock; the run also
   records the recovery overhead vs the fault-free fleet.

4. **Golden in_flight=1** — with the retry machinery enabled but zero
   injected failures, every job's single-job fleet trace at one worker /
   ``in_flight=1`` must be bit-identical to the frozen sequential driver
   (``sequential_run_search``) on a replayed record — failure handling
   must cost nothing when nothing fails.

Writes ``BENCH_fleet.json``; exits non-zero when a target is violated.

    PYTHONPATH=src python -m benchmarks.bench_fleet [--smoke] [--threads]
        [--out BENCH_fleet.json] [--min-speedup 3] [--max-warm-ratio 0.5]
        [--min-fault-speedup 2]
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import tempfile
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core import SPECS, record_space
from repro.core.evaluate import TEST_OVERHEAD
from repro.fleet import (FleetTuner, ThreadWorkerPool, VirtualWorkerPool,
                         job_from_registry)
from repro.kernels.registry import BENCHMARKS
from repro.tuning import ConfigStore

SCHEMA = "repro.bench_fleet"
VERSION = 1

KERNELS = (("matmul", "2048"), ("transpose", "8192"), ("conv2d", "4096"))
HW = ("tpu_v4", "tpu_v5e")
WELL_FACTOR = 1.1


def _result_row(r, threshold: Optional[float] = None) -> Dict:
    row = {
        "job": r.job, "bucket": r.bucket, "hardware": r.hardware,
        "searcher": r.searcher, "warm_started": r.warm_started,
        "trials": r.trials, "best_runtime_s": r.best_runtime,
        "best_config": r.best_config, "elapsed_s": r.elapsed,
        "busy_s": r.busy,
    }
    if threshold is not None:
        row["well_threshold_s"] = threshold
        row["trials_to_well"] = r.trials_to_threshold(threshold)
    return row


def _cold_jobs(budget: int, seed: int) -> List:
    return [job_from_registry(k, inp, hw, budget=budget, seed=seed,
                              searcher="random")
            for k, inp in KERNELS for hw in HW]


def run_speedup(workers: int, budget: int, seed: int,
                threads: bool) -> Dict:
    """Identical cold work, scheduled 1-wide vs ``workers``-wide."""
    def run(n_workers: int) -> Dict:
        pool = VirtualWorkerPool(workers=n_workers)
        rep = FleetTuner(_cold_jobs(budget, seed), pool, store=None,
                         in_flight=n_workers, publish_models=False).run()
        return {"workers": n_workers, "in_flight": n_workers,
                "elapsed_s": rep.elapsed, "busy_s": rep.busy,
                "trials": int(sum(r.trials for r in rep.results))}

    seq = run(1)
    fleet = run(workers)
    out = {
        "jobs": len(KERNELS) * len(HW),
        "budget_per_job": budget,
        "sequential": seq,
        "fleet": fleet,
        "speedup": seq["elapsed_s"] / fleet["elapsed_s"],
        "identical_work": seq["trials"] == fleet["trials"]
        and abs(seq["busy_s"] - fleet["busy_s"]) < 1e-9,
    }
    if threads:
        out["thread"] = run_thread_speedup(workers, budget, seed)
    return out


def run_thread_speedup(workers: int, budget: int, seed: int,
                       target_busy_s: float = 3.0) -> Dict:
    """Same fleet on REAL threads: each measurement sleeps its simulated
    cost (scaled so the sequential run is ~``target_busy_s`` of honest
    wall time), so the reported speedup is genuine concurrency."""
    def make_jobs(scale: float) -> List:
        jobs = _cold_jobs(budget, seed)
        for job in jobs:
            space, wl, hw = job.space, job.workload_fn, job.hw_spec()
            def eval_fn(index, profile, _space=space, _wl=wl, _hw=hw,
                        _scale=scale):
                from repro.core import costmodel
                cs = costmodel.execute(_wl(_space[index]), _hw)
                cost = (float(cs.runtime) + TEST_OVERHEAD) * _scale
                time.sleep(cost)
                return float(cs.runtime), None, cost
            job.eval_fn = eval_fn
        return jobs

    # pre-compute total simulated cost to pick the sleep scale
    busy = 0.0
    for k, inp in KERNELS:
        bm = BENCHMARKS[k]
        space = bm.make_space()
        # the random searcher at this seed visits this exact prefix
        order = np.random.default_rng(seed).permutation(len(space))
        for hw in HW:
            rec = record_space(space, lambda c: bm.workload_fn(
                c, bm.inputs[inp]), SPECS[hw])
            busy += float(sum(rec.runtimes[i] + TEST_OVERHEAD
                              for i in order[:budget]))
    scale = target_busy_s / busy

    def run(n_workers: int) -> Dict:
        pool = ThreadWorkerPool(workers=n_workers)
        try:
            t0 = time.perf_counter()
            rep = FleetTuner(make_jobs(scale), pool, store=None,
                             in_flight=n_workers,
                             publish_models=False).run()
            wall = time.perf_counter() - t0
        finally:
            pool.close()
        return {"workers": n_workers, "wall_s": wall,
                "busy_s": rep.busy,
                "trials": int(sum(r.trials for r in rep.results))}

    seq = run(1)
    fleet = run(workers)
    return {"sleep_scale": scale, "sequential": seq, "fleet": fleet,
            "speedup": seq["wall_s"] / fleet["wall_s"]}


def run_faults(workers: int, budget: int, seed: int,
               seq_elapsed: float, fleet_elapsed: float,
               min_fault_speedup: float) -> Dict:
    """The acceptance scenario: kill 1 of ``workers`` lanes mid-run, fail
    10% of tests (seeded rng — bit-reproducible), and verify the fleet
    completes everything with bounded retries, honest abandoned-cost
    accounting, and ≥ ``min_fault_speedup``x over fault-free sequential."""
    kill_at = 0.5 * fleet_elapsed          # mid-run on the virtual clock
    pool = VirtualWorkerPool(workers=workers, fail_rate=0.10,
                             fail_seed=seed,
                             kill_lane_at={workers - 1: kill_at})
    rep = FleetTuner(_cold_jobs(budget, seed), pool, store=None,
                     in_flight=workers, publish_models=False,
                     retries=2).run()
    all_complete = all(r.trials == budget and len(r.history) == budget
                      for r in rep.results)
    speedup = seq_elapsed / rep.elapsed
    return {
        "jobs": len(KERNELS) * len(HW),
        "budget_per_job": budget,
        "fail_rate": 0.10,
        "killed_lane": workers - 1,
        "kill_at_s": kill_at,
        "elapsed_s": rep.elapsed,
        "busy_s": rep.busy,
        "abandoned_s": rep.abandoned,
        "failures": rep.failures,
        "known_bad": rep.known_bad,
        "max_retries_used": rep.max_retries_used,
        "trials": int(sum(r.trials for r in rep.results)),
        "all_jobs_complete": all_complete,
        "retries_bounded": rep.max_retries_used <= 2,
        "abandoned_accounted": rep.failures > 0 and rep.abandoned > 0.0,
        "speedup_vs_sequential": speedup,
        "meets_fault_speedup_target": speedup >= min_fault_speedup,
        "recovery_overhead": rep.elapsed / fleet_elapsed,
    }


def run_golden(budget: int, seed: int) -> Dict:
    """Zero-failure equivalence: each job alone on a 1-lane pool at
    ``in_flight=1`` — with retries enabled — replays the frozen sequential
    driver bit-for-bit (same (steps, elapsed, runtime) trace rows)."""
    from repro.core.searcher import make_searcher, sequential_run_search
    from repro.core.evaluate import ReplayEvaluator

    checked, identical = 0, True
    for job in _cold_jobs(budget, seed):
        pool = VirtualWorkerPool(workers=1)
        rep = FleetTuner([job], pool, store=None, in_flight=1,
                         publish_models=False, retries=2).run()
        rec = record_space(job.space, job.workload_fn, job.hw_spec())
        searcher = make_searcher("random", job.space, seed=seed)
        ev = ReplayEvaluator(rec)
        sequential_run_search(searcher, ev, budget)
        if rep.results[0].trace != ev.trace:
            identical = False
        checked += 1
    return {"jobs_checked": checked, "bit_identical": identical}


def run_warmstart(workers: int, budget: int, seed: int,
                  store_path: str) -> Dict:
    """Wave 1 cold on HW[0] (publishes artifacts), wave 2 warm on HW[1]."""
    store = ConfigStore(store_path)
    pool = VirtualWorkerPool(workers=workers)
    waves = []
    for hw in HW:
        jobs = [job_from_registry(k, inp, hw, budget=budget, seed=seed)
                for k, inp in KERNELS]
        rep = FleetTuner(jobs, pool, store=store, in_flight=workers).run()
        rows = []
        for r in rep.results:
            kernel = r.job.split("/", 1)[0]
            bm = BENCHMARKS[kernel]
            rec = record_space(
                bm.make_space(),
                lambda c: bm.workload_fn(c, bm.inputs[r.bucket]),
                SPECS[hw])
            rows.append(_result_row(
                r, threshold=rec.best_runtime * WELL_FACTOR))
        waves.append({"hardware": hw, "elapsed_s": rep.elapsed,
                      "busy_s": rep.busy, "jobs": rows})

    def t2w(row) -> int:
        # censored at the budget when never reached (conservative)
        v = row["trials_to_well"]
        return int(v) if v is not None else int(row["trials"])

    cold = [t2w(row) for row in waves[0]["jobs"]]
    warm = [t2w(row) for row in waves[1]["jobs"]]
    return {
        "budget_per_job": budget,
        "well_factor": WELL_FACTOR,
        "wave1_cold": waves[0],
        "wave2_warm": waves[1],
        "cold_trials_to_well": cold,
        "warm_trials_to_well": warm,
        "cold_mean_trials_to_well": float(np.mean(cold)),
        "warm_mean_trials_to_well": float(np.mean(warm)),
        "warm_cold_ratio": float(np.mean(warm) / np.mean(cold)),
        "all_wave2_warm_started": all(row["warm_started"]
                                      for row in waves[1]["jobs"]),
        "store_entries": len(store),
    }


def run_benchmark(workers: int, budget: int, warm_budget: int, seed: int,
                  store_path: str, min_speedup: float,
                  max_warm_ratio: float, threads: bool,
                  min_fault_speedup: float) -> Dict:
    speedup = run_speedup(workers, budget, seed, threads)
    warm = run_warmstart(workers, warm_budget, seed, store_path)
    faults = run_faults(workers, budget, seed,
                        seq_elapsed=speedup["sequential"]["elapsed_s"],
                        fleet_elapsed=speedup["fleet"]["elapsed_s"],
                        min_fault_speedup=min_fault_speedup)
    golden = run_golden(budget, seed)
    summary = {
        "speedup": speedup["speedup"],
        "meets_speedup_target": speedup["speedup"] >= min_speedup,
        "identical_work": speedup["identical_work"],
        "warm_cold_ratio": warm["warm_cold_ratio"],
        "meets_warmstart_target":
            warm["warm_cold_ratio"] <= max_warm_ratio,
        "all_wave2_warm_started": warm["all_wave2_warm_started"],
        "fault_speedup": faults["speedup_vs_sequential"],
        "fault_recovery_overhead": faults["recovery_overhead"],
        "meets_fault_targets": (
            faults["all_jobs_complete"] and faults["retries_bounded"]
            and faults["abandoned_accounted"]
            and faults["meets_fault_speedup_target"]),
        "golden_in_flight_1": golden["bit_identical"],
    }
    violations = []
    if not summary["meets_speedup_target"]:
        violations.append(
            f"fleet speedup {summary['speedup']:.2f}x < {min_speedup}x")
    if not summary["identical_work"]:
        violations.append("sequential and fleet runs did different work")
    if not summary["meets_warmstart_target"]:
        violations.append(
            f"warm/cold trials-to-well ratio "
            f"{summary['warm_cold_ratio']:.3f} > {max_warm_ratio}")
    if not summary["all_wave2_warm_started"]:
        violations.append("a wave-2 job failed to warm-start from the store")
    if not faults["all_jobs_complete"]:
        violations.append("faulted fleet dropped results (a job did not "
                          "resolve its full budget)")
    if not faults["retries_bounded"]:
        violations.append(
            f"a failed test needed {faults['max_retries_used']} retries "
            "(> 2)")
    if not faults["abandoned_accounted"]:
        violations.append("fault run produced no abandoned-cost accounting")
    if not faults["meets_fault_speedup_target"]:
        violations.append(
            f"faulted-fleet speedup {faults['speedup_vs_sequential']:.2f}x "
            f"< {min_fault_speedup}x over fault-free sequential")
    if not golden["bit_identical"]:
        violations.append("zero-failure driver trace diverged from the "
                          "frozen sequential baseline at in_flight=1")
    return {
        "schema": SCHEMA,
        "version": VERSION,
        "generated": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "host": {"python": platform.python_version(),
                 "numpy": np.__version__,
                 "machine": platform.machine()},
        "workload": {
            "kernels": [list(k) for k in KERNELS],
            "hardware": list(HW),
            "seed": seed,
        },
        "targets": {"min_speedup": min_speedup,
                    "max_warm_ratio": max_warm_ratio,
                    "min_fault_speedup": min_fault_speedup,
                    "workers": workers},
        "speedup": speedup,
        "warmstart": warm,
        "faults": faults,
        "golden": golden,
        "summary": summary,
        "violations": violations,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--out", default="BENCH_fleet.json")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--budget", type=int, default=24,
                    help="per-job trial budget for the speedup experiment")
    ap.add_argument("--warm-budget", type=int, default=60,
                    help="per-job trial budget for the warm-start waves")
    ap.add_argument("--seed", type=int, default=11)
    ap.add_argument("--store", default=None,
                    help="warm-start store path (default: fresh temp file)")
    ap.add_argument("--min-speedup", type=float, default=3.0)
    ap.add_argument("--max-warm-ratio", type=float, default=0.5)
    ap.add_argument("--min-fault-speedup", type=float, default=2.0,
                    help="required speedup of the faulted fleet (1 dead "
                    "lane + 10%% failing tests) over fault-free sequential")
    ap.add_argument("--threads", action="store_true",
                    help="also measure the real ThreadWorkerPool speedup")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: smaller budgets, no thread timing")
    args = ap.parse_args(argv)

    budget, warm_budget, threads = args.budget, args.warm_budget, args.threads
    if args.smoke:
        budget, warm_budget, threads = 18, 40, False

    if args.store is not None:
        result = run_benchmark(args.workers, budget, warm_budget, args.seed,
                               args.store, args.min_speedup,
                               args.max_warm_ratio, threads,
                               args.min_fault_speedup)
    else:
        with tempfile.TemporaryDirectory() as td:
            result = run_benchmark(args.workers, budget, warm_budget,
                                   args.seed,
                                   os.path.join(td, "fleet_store.json"),
                                   args.min_speedup, args.max_warm_ratio,
                                   threads, args.min_fault_speedup)

    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    s = result["summary"]
    print(f"wrote {args.out}")
    print(f"fleet speedup at {args.workers} workers: {s['speedup']:.2f}x "
          f"(target >= {args.min_speedup}x: "
          f"{'PASS' if s['meets_speedup_target'] else 'FAIL'})")
    if "thread" in result["speedup"]:
        print(f"  real thread-pool speedup: "
              f"{result['speedup']['thread']['speedup']:.2f}x")
    print(f"warm/cold trials-to-well: "
          f"{result['warmstart']['warm_mean_trials_to_well']:.1f} / "
          f"{result['warmstart']['cold_mean_trials_to_well']:.1f} "
          f"= {s['warm_cold_ratio']:.3f} (target <= {args.max_warm_ratio}: "
          f"{'PASS' if s['meets_warmstart_target'] else 'FAIL'})")
    f = result["faults"]
    print(f"fault injection (1 dead lane, 10% failing tests): "
          f"{s['fault_speedup']:.2f}x vs sequential "
          f"(target >= {args.min_fault_speedup}x), recovery overhead "
          f"{s['fault_recovery_overhead']:.2f}x, {f['failures']} failed "
          f"attempts, {f['known_bad']} known-bad, "
          f"{f['abandoned_s']:.3f}s abandoned: "
          f"{'PASS' if s['meets_fault_targets'] else 'FAIL'}")
    print(f"zero-failure golden (in_flight=1 vs frozen sequential): "
          f"{'PASS' if s['golden_in_flight_1'] else 'FAIL'}")
    if result["violations"]:
        print("TARGETS VIOLATED:\n  " + "\n  ".join(result["violations"]),
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
