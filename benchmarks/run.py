"""Benchmark harness — one section per paper table/figure, plus the
dry-run roofline table and a ``session`` section exercising the public
``repro.tuning`` API (train → save JSON artifact → load → tune).  Usage:

    PYTHONPATH=src python -m benchmarks.run [--reps N] [--only table5,...]
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--reps", type=int, default=200,
                    help="search repetitions (paper: 1000)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. table5,fig")
    args = ap.parse_args()

    from benchmarks import paper_tables as T

    sections = {
        "table4": lambda: T.table4_random_steps(args.reps),
        "table5": lambda: T.table5_profile_vs_random(args.reps),
        "table6": lambda: T.table6_hw_portability(max(args.reps * 3 // 4, 20)),
        "table7": lambda: T.table7_input_portability(max(args.reps * 3 // 4, 20)),
        "fig": lambda: T.fig_convergence(max(args.reps * 3 // 10, 10)),
        "table8": lambda: T.table8_starchart(max(args.reps // 5, 10)),
        "table9": lambda: T.table9_cross_hw_starchart(max(args.reps // 5, 10)),
        "basin": lambda: T.table_basin_hopping(max(args.reps * 3 // 10, 10)),
        "session": lambda: T.session_portability_demo(),
        "roofline": _roofline_section,
    }
    wanted = args.only.split(",") if args.only else list(sections)
    t0 = time.time()
    table4 = None
    for name in wanted:
        t = time.time()
        if name == "table5" and table4 is not None:
            T.table5_profile_vs_random(args.reps, t4=table4)
        elif name == "table4":
            table4 = sections[name]()
        else:
            sections[name]()
        print(f"[{name}: {time.time() - t:.1f}s]")
    print(f"\nTotal: {time.time() - t0:.1f}s")


def _roofline_section():
    """§Roofline summary from the dry-run record (see EXPERIMENTS.md)."""
    import json
    import os
    path = os.path.join(os.path.dirname(__file__), "..",
                        "dryrun_results.jsonl")
    if not os.path.exists(path):
        print("\n## Roofline: dryrun_results.jsonl missing — run "
              "scripts_run_dryrun_all.sh first")
        return
    print("\n## Roofline (single-pod 16x16, per step; from the dry-run "
          "compiled artifacts)")
    hdr = (f"{'arch':24s}{'shape':12s}{'compute':>10}{'memory':>10}"
           f"{'collect':>10}{'bound':>12}{'useful':>8}")
    print(hdr)
    best = {}
    for line in open(path):
        r = json.loads(line)
        if r.get("status") != "ok" or r.get("mesh") != "single":
            continue
        best[(r["arch"], r["shape"])] = r
    for (arch, shape), r in sorted(best.items()):
        rf = r["roofline"]
        print(f"{arch:24s}{shape:12s}"
              f"{rf['compute_s']*1e3:9.1f}ms{rf['memory_s']*1e3:9.1f}ms"
              f"{rf['collective_s']*1e3:9.1f}ms"
              f"{rf['dominant']:>12}"
              f"{rf['useful_flops_ratio']:8.2f}")


if __name__ == "__main__":
    main()
