"""Generate the §Dry-run and §Roofline tables of EXPERIMENTS.md from the
sweep records (baseline + optimized), plus a §Kernel-coverage table discovered
through the registry (``repro.kernels.registry.BENCHMARKS``).

Usage (from the repo root):

    PYTHONPATH=src python -m benchmarks.gen_experiments [--kernels-only]
"""
import argparse
import json


def load(path):
    recs = {}
    try:
        for line in open(path):
            r = json.loads(line)
            recs[(r["arch"], r["shape"], r.get("mesh"))] = r
    except FileNotFoundError:
        pass
    return recs


def gib(x):
    return f"{x / 2**30:.2f}"


def kernel_table():
    """Tuning-space coverage per registered kernel benchmark — discovered
    lazily via the decorator-based registry, so a new kernel package shows
    up here without touching this script."""
    from repro.kernels.registry import BENCHMARKS

    print("### Kernel benchmark coverage (registry-discovered)\n")
    print("| benchmark | configs | parameters | binary | inputs |")
    print("|---|---|---|---|---|")
    for name in BENCHMARKS:
        bm = BENCHMARKS[name]
        sp = bm.make_space()
        params = ", ".join(
            f"{p.name}({len(p.values)})" for p in sp.parameters)
        print(f"| {name} | {len(sp)} | {params} "
              f"| {len(sp.binary_parameters)} | {len(bm.inputs)} |")


def problem_table():
    """Tuning-problem coverage across every registered kind — kernels,
    train-step sharding, serve geometry — discovered through the problem
    registry (``repro.tuning.problem``), so a new problem kind shows up
    here without touching this script."""
    from repro.tuning.problem import list_problems, parse_problem

    print("\n### Tuning-problem coverage (registry-discovered)\n")
    print("| problem | kind | space | configs | bucket |")
    print("|---|---|---|---|---|")
    for spec in list_problems():
        p = parse_problem(spec)
        sp = p.space()
        print(f"| {spec} | {p.kind} | {sp.name} | {len(sp)} "
              f"| {p.bucket} |")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--kernels-only", action="store_true",
                    help="print only the registry-discovered kernel table")
    ap.add_argument("--problems-only", action="store_true",
                    help="print only the registry-discovered problem table")
    args = ap.parse_args()

    if args.problems_only:
        problem_table()
        return
    kernel_table()
    problem_table()
    if args.kernels_only:
        return

    base = load("dryrun_results.jsonl")
    opt = load("dryrun_results_opt.jsonl")

    print("\n### Dry-run table (per device; single = 16x16/256 chips, "
          "multi = 2x16x16/512 chips)\n")
    print("| arch | shape | mesh | status | args GiB | temp GiB | "
          "GFLOP/dev | coll GB/chip | compile s |")
    print("|---|---|---|---|---|---|---|---|---|")
    for key in sorted(base):
        r = base[key]
        if r["status"] == "skipped":
            print(f"| {key[0]} | {key[1]} | {key[2]} | SKIP ({r['reason'][:40]}) "
                  f"| – | – | – | – | – |")
            continue
        if r["status"] != "ok":
            print(f"| {key[0]} | {key[1]} | {key[2]} | {r['status']} "
                  f"| – | – | – | – | – |")
            continue
        rf = r["roofline"]
        print(f"| {key[0]} | {key[1]} | {key[2]} | ok "
              f"| {gib(r['memory']['argument_bytes'])} "
              f"| {gib(r['memory']['temp_bytes'])} "
              f"| {rf['flops'] / rf['chips'] / 1e9:.0f} "
              f"| {rf['collective_bytes'] / 1e9:.2f} "
              f"| {r['compile_s']} |")

    print("\n### Roofline table — BASELINE vs OPTIMIZED (single-pod, "
          "per step, seconds)\n")
    print("| arch | shape | compute | memory | collective | bound | "
          "useful | opt compute | opt memory | opt coll | opt useful |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for key in sorted(base):
        if key[2] != "single":
            continue
        r = base[key]
        if r["status"] != "ok":
            continue
        rf = r["roofline"]
        o = opt.get(key)
        of = o["roofline"] if o and o.get("status") == "ok" else None
        opt_cells = (
            f"| {of['compute_s']:.2f} | {of['memory_s']:.2f} "
            f"| {of['collective_s']:.2f} | {of['useful_flops_ratio']:.2f} |"
            if of else "| – | – | – | – |")
        print(f"| {key[0]} | {key[1]} "
              f"| {rf['compute_s']:.2f} | {rf['memory_s']:.2f} "
              f"| {rf['collective_s']:.2f} | {rf['dominant']} "
              f"| {rf['useful_flops_ratio']:.2f} "
              + opt_cells)


if __name__ == "__main__":
    main()
