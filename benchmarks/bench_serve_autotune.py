"""Shifting-workload benchmark for the online serving autotuner.

Replays a synthetic serving workload whose shape mix shifts through phases
(short-prompt/short-gen → long/long → medium), against the deterministic
``SyntheticServeBackend`` (cost model on a true hardware spec + seeded
jitter + host overhead the portable model does not know about).  For every
drift event it compares the drift-triggered online tuner against the oracle
(exhaustive measurement of every feasible configuration on the same
calibration wave) and counts live trials; then a SECOND run over the same
``ConfigStore`` must reach the same configurations with **zero** live trials
(pure reuse).  Writes ``BENCH_serve_autotune.json``.

Acceptance targets (ISSUE 3): recovery ≥ 90% of oracle throughput within
≤ 10 live trials per drift event; second run pure reuse.

Usage (from the repo root):

    PYTHONPATH=src python -m benchmarks.bench_serve_autotune \
        [--out BENCH_serve_autotune.json] [--min-recovery 0.9]
        [--max-trials 10] [--ticks 6] [--requests 24] [--seed 0]
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import tempfile
import time
from typing import Dict, List

import numpy as np

from repro.core.hwspec import SPECS
from repro.serve.autotune import (OnlineAutotuner, ServeWorkloadStats,
                                  ShapeBucketer, SyntheticServeBackend,
                                  serve_space)
from repro.serve.engine import Request
from repro.tuning.store import ConfigStore

SCHEMA = "repro.bench_serve_autotune"
VERSION = 1

# (mean prompt len, mean max-new) per phase of the shifting workload
PHASES = ((12, 6), (80, 28), (40, 12))
TRUE_HW = "tpu_v4"      # the hardware the synthetic backend "is"
TRAIN_HW = "tpu_v5e"    # the portable model trains on DIFFERENT hardware


def make_workload(phases, ticks_per_phase: int, requests_per_tick: int,
                  bucketer: ShapeBucketer, seed: int) -> List[List[Request]]:
    """Deterministic request stream: ``ticks_per_phase`` ticks per phase."""
    rng = np.random.default_rng(seed)
    stream: List[List[Request]] = []
    uid = 0
    for plen_c, new_c in phases:
        for _ in range(ticks_per_phase):
            tick = []
            for _ in range(requests_per_tick):
                plen = int(np.clip(rng.normal(plen_c, 2.0), 1,
                                   bucketer.max_prompt))
                new = int(np.clip(rng.normal(new_c, 1.0), 1,
                                  bucketer.max_new))
                tick.append(Request(uid=uid, prompt=np.ones(plen, np.int32),
                                    max_new_tokens=new))
                uid += 1
            stream.append(tick)
    return stream


def oracle_best(backend: SyntheticServeBackend, space, bucketer, bucket,
                calib) -> Dict:
    """Exhaustive best over feasible configs on the same calibration wave
    (out-of-band: does not touch the backend's trial accounting)."""
    n = len(calib)
    plen = max(len(r.prompt) for r in calib)
    new = max(r.max_new_tokens for r in calib)
    best_rt, best_cfg, feasible = float("inf"), None, 0
    for i in range(len(space)):
        cfg = space[i]
        rt = backend.latency(cfg, n, plen, new)
        if rt < 1e2:  # feasible
            feasible += 1
            if rt < best_rt:
                best_rt, best_cfg = rt, dict(cfg)
    return {"runtime_s": best_rt, "config": best_cfg,
            "feasible_configs": feasible}


def run_once(store: ConfigStore, stream, bucketer, stats, seed: int) -> Dict:
    backend = SyntheticServeBackend(SPECS[TRUE_HW], stats, seed=seed)
    tuner = OnlineAutotuner(backend, store=store, bucketer=bucketer,
                            hw=SPECS[TRUE_HW], train_hw=SPECS[TRAIN_HW],
                            stats=stats, seed=seed)
    events = []
    tokens = 0
    for t, tick in enumerate(stream):
        _, rep = tuner.serve(tick)
        tokens += sum(r.max_new_tokens for r in tick)
        if rep is not None and rep.drift:
            calib = [r for r in tick
                     if bucketer.request_bucket(r).key == rep.bucket]
            calib = calib[: tuner.calib_n] or list(tick)[: tuner.calib_n]
            bucket = bucketer.request_bucket(calib[0])
            oracle = oracle_best(backend, tuner.space, bucketer, bucket,
                                 calib)
            tuned_rt = backend.latency(
                rep.config, len(calib),
                max(len(r.prompt) for r in calib),
                max(r.max_new_tokens for r in calib))
            events.append({
                "tick": t,
                "bucket": rep.bucket,
                "reused": rep.reused,
                "live_trials": rep.live_trials,
                "config": rep.config,
                "tuned_runtime_s": tuned_rt,
                "oracle_runtime_s": oracle["runtime_s"],
                "oracle_config": oracle["config"],
                "feasible_configs": oracle["feasible_configs"],
                # throughput recovery: oracle latency / achieved latency
                "recovery": oracle["runtime_s"] / tuned_rt,
            })
    return {
        "events": events,
        "total_live_trials": int(backend.measure_calls),
        "served_tokens": int(tokens),
        "virtual_serve_time_s": float(backend.virtual_time),
        "virtual_tok_per_s": float(tokens / backend.virtual_time)
        if backend.virtual_time else None,
    }


def run_benchmark(ticks_per_phase: int, requests_per_tick: int, seed: int,
                  store_path: str, min_recovery: float, max_trials: int
                  ) -> Dict:
    bucketer = ShapeBucketer(max_prompt=96, max_new=32)
    stats = ServeWorkloadStats()
    space = serve_space()
    stream = make_workload(PHASES, ticks_per_phase, requests_per_tick,
                           bucketer, seed)

    store = ConfigStore(store_path)
    run1 = run_once(store, stream, bucketer, stats, seed)
    # second run: a FRESH tuner/backend over the SAME persisted store — the
    # restart scenario; every drift event must be pure reuse
    store2 = ConfigStore(store_path)
    run2 = run_once(store2, stream, bucketer, stats, seed)

    recoveries = [e["recovery"] for e in run1["events"]]
    trials = [e["live_trials"] for e in run1["events"]]
    same_cfg = all(
        e2["config"] == e1["config"]
        for e1, e2 in zip(run1["events"], run2["events"]))
    summary = {
        "drift_events_run1": len(run1["events"]),
        "min_recovery": float(min(recoveries)) if recoveries else None,
        "max_live_trials_per_event": int(max(trials)) if trials else 0,
        "run2_total_live_trials": run2["total_live_trials"],
        "run2_pure_reuse": (run2["total_live_trials"] == 0
                            and all(e["reused"] for e in run2["events"])),
        "run2_same_configs": same_cfg,
        "meets_recovery_target": bool(recoveries
                                      and min(recoveries) >= min_recovery),
        "meets_trial_budget": bool(trials and max(trials) <= max_trials),
    }
    violations = []
    if not summary["meets_recovery_target"]:
        violations.append(
            f"min recovery {summary['min_recovery']} < {min_recovery}")
    if not summary["meets_trial_budget"]:
        violations.append(
            f"max live trials {summary['max_live_trials_per_event']} "
            f"> {max_trials}")
    if not summary["run2_pure_reuse"]:
        violations.append(
            f"second run spent {run2['total_live_trials']} live trials "
            "(expected 0: pure store reuse)")
    if not same_cfg:
        violations.append("second run served different configs than run 1")
    return {
        "schema": SCHEMA,
        "version": VERSION,
        "generated": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "host": {"python": platform.python_version(),
                 "numpy": np.__version__,
                 "machine": platform.machine()},
        "workload": {
            "phases": [list(p) for p in PHASES],
            "ticks_per_phase": ticks_per_phase,
            "requests_per_tick": requests_per_tick,
            "seed": seed,
            "bucketer": {"max_prompt": bucketer.max_prompt,
                         "max_new": bucketer.max_new},
        },
        "space": {"name": space.name, "n_configs": len(space),
                  "parameters": {p.name: list(p.values)
                                 for p in space.parameters}},
        "hardware": {"true": TRUE_HW, "model_train": TRAIN_HW},
        "targets": {"min_recovery": min_recovery,
                    "max_live_trials": max_trials},
        "run1": run1,
        "run2": run2,
        "summary": summary,
        "violations": violations,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--out", default="BENCH_serve_autotune.json")
    ap.add_argument("--store", default=None,
                    help="ConfigStore path (default: fresh temp file, so "
                    "run 1 always starts cold)")
    ap.add_argument("--ticks", type=int, default=6,
                    help="ticks per workload phase")
    ap.add_argument("--requests", type=int, default=24,
                    help="requests per tick")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--min-recovery", type=float, default=0.9,
                    help="fail (exit 1) if any drift event recovers less "
                    "than this fraction of oracle throughput")
    ap.add_argument("--max-trials", type=int, default=10,
                    help="fail (exit 1) if any drift event spends more "
                    "live trials than this")
    args = ap.parse_args(argv)

    if args.store is not None:
        store_path = args.store
        result = run_benchmark(args.ticks, args.requests, args.seed,
                               store_path, args.min_recovery, args.max_trials)
    else:
        with tempfile.TemporaryDirectory() as td:
            store_path = os.path.join(td, "serve_store.json")
            result = run_benchmark(args.ticks, args.requests, args.seed,
                                   store_path, args.min_recovery,
                                   args.max_trials)

    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    s = result["summary"]
    print(f"wrote {args.out}")
    print(f"drift events: {s['drift_events_run1']}, "
          f"min recovery {s['min_recovery']:.3f} "
          f"(target >= {args.min_recovery}: "
          f"{'PASS' if s['meets_recovery_target'] else 'FAIL'})")
    print(f"max live trials/event: {s['max_live_trials_per_event']} "
          f"(target <= {args.max_trials}: "
          f"{'PASS' if s['meets_trial_budget'] else 'FAIL'})")
    print(f"second run: {s['run2_total_live_trials']} live trials "
          f"(pure reuse: {'PASS' if s['run2_pure_reuse'] else 'FAIL'})")
    if result["violations"]:
        print("TARGETS VIOLATED:\n  " + "\n  ".join(result["violations"]),
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
