"""Whole-system tuning benchmark: kernels + sharding + serve, one fleet.

Three experiments on deterministic synthetic backends (everything priced
through the cost model / analytic sharding model on virtual pools — every
number is bit-reproducible):

1. **Sharding model fidelity** — train the portable TP→PC_ops model on the
   sharding problem's counter workload (the paper's deliberate sample and
   the full space), price its predictions through the cost model on the
   target hardware, and rank-correlate against the measured backend (which
   applies hardware skews + seeded jitter the model never sees).  Target:
   Spearman ≥ ``--min-spearman`` (default 0.8) on the FULL-sample rows —
   counter features trained on roofline-style workload counters must rank
   mesh/FSDP/SEQ/GA layouts.  Deliberate-sample rows are reported
   informationally: on a 72-config space the deliberate design trains the
   tree on a handful of configs, so its rank fidelity is seed-sensitive
   and is not a stable CI gate.

2. **Whole-system warm start** — ``system_problems(arch)``: every
   registered kernel + train-step sharding + serve wave geometry for one
   model-zoo entry, through ONE fleet and ONE store.  Wave 1 tunes cold on
   the first hardware (publishing portable artifacts), wave 2 tunes the
   same system on the second hardware, warm-starting from the store.
   Convergence = trials until within ``WELL_FACTOR`` of each problem's
   exhaustive best on that hardware.  Target: warm mean trials-to-well ≤
   ``--max-warm-ratio`` × cold (default 0.6).

3. **Kernel adapter golden** — every registered kernel routed through the
   ``KernelProblem`` adapter (``job_from_problem``) must produce a
   bit-identical single-lane trace to the legacy ``job_from_registry``
   path: the unified abstraction costs nothing on the kernel tier.

Writes ``BENCH_systems.json``; exits non-zero when a target is violated.

    PYTHONPATH=src python -m benchmarks.bench_systems [--smoke]
        [--out BENCH_systems.json] [--min-spearman 0.8]
        [--max-warm-ratio 0.6]
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import tempfile
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core import SPECS
from repro.core.tuner import predicted_runtimes
from repro.fleet import (FleetTuner, VirtualWorkerPool, job_from_problem,
                         job_from_registry)
from repro.tuning import ConfigStore, TuningSession
from repro.tuning.problem import KernelProblem, system_problems

SCHEMA = "repro.bench_systems"
VERSION = 1

ARCH = "qwen2.5-3b"
HW = ("tpu_v4", "tpu_v5e")
WELL_FACTOR = 1.1


def spearman(a, b) -> float:
    """Spearman rank correlation without scipy."""
    a = np.asarray(a, dtype=float)
    b = np.asarray(b, dtype=float)
    ra = np.argsort(np.argsort(a)).astype(float)
    rb = np.argsort(np.argsort(b)).astype(float)
    ra -= ra.mean()
    rb -= rb.mean()
    denom = float(np.sqrt((ra * ra).sum() * (rb * rb).sum()))
    return float((ra * rb).sum() / denom) if denom > 0 else 0.0


def run_sharding_fidelity(seed: int, min_spearman: float) -> Dict:
    """TP→PC model on the sharding space vs the skewed/jittered oracle."""
    from repro.distributed.tuning import ShardingProblem

    problem = ShardingProblem.from_name(f"{ARCH}/train_4k", seed=seed)
    space = problem.space()
    wl = problem.workload_fn()
    rows = []
    for hw_name in HW:
        hw = SPECS[hw_name]
        measured = np.array([problem.measured_runtime(space[i], hw)
                             for i in range(len(space))])
        for sample in ("deliberate", "full"):
            session = TuningSession(space, wl, hw=hw, seed=seed)
            session.train(kind="tree", sample=sample)
            pred = predicted_runtimes(session.model, space, hw)
            rho = spearman(pred, measured)
            # top-1 regret: how far the best-predicted layout is from the
            # true optimum (the warm-start walks this ranking first)
            best_pred = int(np.argsort(pred, kind="stable")[0])
            regret = float(measured[best_pred] / measured.min())
            rows.append({
                "hardware": hw_name, "sample": sample,
                "configs": len(space), "spearman": rho,
                "top1_regret": regret,
                "measured_spread": float(measured.max() / measured.min()),
                # only full-sample rows gate (see module docstring)
                "gated": sample == "full",
                "meets_target": rho >= min_spearman,
            })
    gated = [r for r in rows if r["gated"]]
    return {
        "problem": problem.spec,
        "space": space.name,
        "rows": rows,
        "min_spearman_observed": min(r["spearman"] for r in gated),
        "all_meet_target": all(r["meets_target"] for r in gated),
    }


def _oracle_best(job) -> float:
    """Exhaustive best runtime of one job on its measurement substrate."""
    if job.eval_fn is not None:
        return min(float(job.eval_fn(i, False)[0])
                   for i in range(len(job.space)))
    from repro.core import costmodel
    hw = job.hw_spec()
    return min(float(costmodel.execute(job.workload_fn(job.space[i]),
                                       hw).runtime)
               for i in range(len(job.space)))


def _result_row(r, threshold: float) -> Dict:
    return {
        "job": r.job, "kind": r.job.split(":", 1)[0],
        "bucket": r.bucket, "hardware": r.hardware,
        "searcher": r.searcher, "warm_started": r.warm_started,
        "trials": r.trials, "best_runtime_s": r.best_runtime,
        "best_config": r.best_config,
        "well_threshold_s": threshold,
        "trials_to_well": r.trials_to_threshold(threshold),
    }


def run_system_warmstart(workers: int, budget: int, seed: int,
                         store_path: str,
                         kernels: Optional[List[str]] = None) -> Dict:
    """One ``--system``-style invocation per hardware: wave 1 cold on
    HW[0] publishes artifacts for all three kinds, wave 2 on HW[1]
    warm-starts every kind from the shared store."""
    store = ConfigStore(store_path)
    pool = VirtualWorkerPool(workers=workers)
    waves = []
    for hw in HW:
        problems = system_problems(ARCH, kernels=kernels)
        jobs = [job_from_problem(p, hw, budget=budget, seed=seed)
                for p in problems]
        rep = FleetTuner(jobs, pool, store=store, in_flight=workers).run()
        rows = []
        for r in sorted(rep.results, key=lambda r: r.job):
            job = next(j for j in jobs if f"{j.kind}:" in r.job
                       and j.bucket == r.bucket)
            rows.append(_result_row(r, _oracle_best(job) * WELL_FACTOR))
        waves.append({"hardware": hw, "elapsed_s": rep.elapsed,
                      "busy_s": rep.busy, "jobs": rows})

    def t2w(row) -> int:
        # censored at the budget when the well is never reached
        v = row["trials_to_well"]
        return int(v) if v is not None else int(row["trials"])

    cold = [t2w(row) for row in waves[0]["jobs"]]
    warm = [t2w(row) for row in waves[1]["jobs"]]
    kinds = sorted({row["kind"] for row in waves[0]["jobs"]})
    return {
        "arch": ARCH,
        "budget_per_job": budget,
        "well_factor": WELL_FACTOR,
        "kinds": kinds,
        "all_three_kinds": {"kernel", "serve", "sharding"} <= set(kinds),
        "wave1_cold": waves[0],
        "wave2_warm": waves[1],
        "cold_trials_to_well": cold,
        "warm_trials_to_well": warm,
        "cold_mean_trials_to_well": float(np.mean(cold)),
        "warm_mean_trials_to_well": float(np.mean(warm)),
        "warm_cold_ratio": float(np.mean(warm) / np.mean(cold)),
        "all_wave2_warm_started": all(row["warm_started"]
                                      for row in waves[1]["jobs"]),
        "store_entries": len(store),
    }


def run_kernel_golden(budget: int, seed: int) -> Dict:
    """Every registered kernel: ``job_from_problem(KernelProblem)`` trace
    must equal the legacy ``job_from_registry`` trace bit-for-bit."""
    from repro.kernels.registry import BENCHMARKS

    checked, identical, diverged = 0, True, []
    for kernel in sorted(BENCHMARKS):
        for input_key in sorted(BENCHMARKS[kernel].inputs):
            legacy = job_from_registry(kernel, input_key, HW[0],
                                       budget=budget, seed=seed)
            adapter = job_from_problem(KernelProblem(kernel, input_key),
                                       HW[0], budget=budget, seed=seed,
                                       name=legacy.name)
            traces = []
            for job in (legacy, adapter):
                pool = VirtualWorkerPool(workers=1)
                rep = FleetTuner([job], pool, store=None, in_flight=1,
                                 publish_models=False).run()
                traces.append(rep.results[0].trace)
            if traces[0] != traces[1]:
                identical = False
                diverged.append(f"{kernel}/{input_key}")
            checked += 1
    return {"pairs_checked": checked, "bit_identical": identical,
            "diverged": diverged}


def run_benchmark(workers: int, budget: int, golden_budget: int, seed: int,
                  store_path: str, min_spearman: float,
                  max_warm_ratio: float,
                  kernels: Optional[List[str]] = None) -> Dict:
    t0 = time.perf_counter()
    fidelity = run_sharding_fidelity(seed, min_spearman)
    warm = run_system_warmstart(workers, budget, seed, store_path,
                                kernels=kernels)
    golden = run_kernel_golden(golden_budget, seed)
    summary = {
        "sharding_spearman_min": fidelity["min_spearman_observed"],
        "meets_spearman_target": fidelity["all_meet_target"],
        "warm_cold_ratio": warm["warm_cold_ratio"],
        "meets_warmstart_target":
            warm["warm_cold_ratio"] <= max_warm_ratio,
        "all_wave2_warm_started": warm["all_wave2_warm_started"],
        "all_three_kinds": warm["all_three_kinds"],
        "kernel_adapter_golden": golden["bit_identical"],
    }
    violations = []
    if not summary["meets_spearman_target"]:
        violations.append(
            f"sharding Spearman {summary['sharding_spearman_min']:.3f} "
            f"< {min_spearman}")
    if not summary["meets_warmstart_target"]:
        violations.append(
            f"system warm/cold trials-to-well ratio "
            f"{summary['warm_cold_ratio']:.3f} > {max_warm_ratio}")
    if not summary["all_wave2_warm_started"]:
        violations.append("a wave-2 job failed to warm-start from the store")
    if not summary["all_three_kinds"]:
        violations.append("the system fleet did not cover all three "
                          "problem kinds")
    if not golden["bit_identical"]:
        violations.append("kernel adapter trace diverged from the legacy "
                          f"registry path: {golden['diverged']}")
    return {
        "schema": SCHEMA,
        "version": VERSION,
        "generated": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "host": {"python": platform.python_version(),
                 "numpy": np.__version__,
                 "machine": platform.machine()},
        "workload": {"arch": ARCH, "hardware": list(HW), "seed": seed,
                     "kernels": kernels},
        "targets": {"min_spearman": min_spearman,
                    "max_warm_ratio": max_warm_ratio,
                    "workers": workers},
        "sharding_fidelity": fidelity,
        "system_warmstart": warm,
        "kernel_golden": golden,
        "summary": summary,
        "violations": violations,
        "host_wall_s": time.perf_counter() - t0,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--out", default="BENCH_systems.json")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--budget", type=int, default=16,
                    help="per-job trial budget for the system waves")
    ap.add_argument("--golden-budget", type=int, default=20,
                    help="trial budget for the kernel-adapter golden check")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--store", default=None,
                    help="system store path (default: fresh temp file)")
    ap.add_argument("--min-spearman", type=float, default=0.8)
    ap.add_argument("--max-warm-ratio", type=float, default=0.6)
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: smaller budgets, 3-kernel system")
    args = ap.parse_args(argv)

    budget, golden_budget, kernels = args.budget, args.golden_budget, None
    if args.smoke:
        budget, golden_budget = 12, 12
        kernels = ["matmul", "transpose", "conv2d"]

    if args.store is not None:
        result = run_benchmark(args.workers, budget, golden_budget,
                               args.seed, args.store, args.min_spearman,
                               args.max_warm_ratio, kernels=kernels)
    else:
        with tempfile.TemporaryDirectory() as td:
            result = run_benchmark(args.workers, budget, golden_budget,
                                   args.seed,
                                   os.path.join(td, "system_store.json"),
                                   args.min_spearman, args.max_warm_ratio,
                                   kernels=kernels)

    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    s = result["summary"]
    print(f"wrote {args.out} ({result['host_wall_s']:.1f}s)")
    frows = result["sharding_fidelity"]["rows"]
    n_gated = sum(1 for r in frows if r["gated"])
    print(f"sharding TP->PC Spearman (worst of {n_gated} full-sample "
          f"rows): {s['sharding_spearman_min']:.4f} (target >= "
          f"{args.min_spearman}: "
          f"{'PASS' if s['meets_spearman_target'] else 'FAIL'})")
    for r in frows:
        if not r["gated"]:
            print(f"  [info] {r['hardware']} {r['sample']}-sample "
                  f"Spearman {r['spearman']:.4f} (not gated)")
    w = result["system_warmstart"]
    print(f"system warm/cold trials-to-well "
          f"({'+'.join(w['kinds'])}, {len(w['cold_trials_to_well'])} jobs): "
          f"{w['warm_mean_trials_to_well']:.1f} / "
          f"{w['cold_mean_trials_to_well']:.1f} = {s['warm_cold_ratio']:.3f} "
          f"(target <= {args.max_warm_ratio}: "
          f"{'PASS' if s['meets_warmstart_target'] else 'FAIL'})")
    g = result["kernel_golden"]
    print(f"kernel adapter golden ({g['pairs_checked']} kernel/input "
          f"pairs): {'PASS' if s['kernel_adapter_golden'] else 'FAIL'}")
    if result["violations"]:
        print("TARGETS VIOLATED:\n  " + "\n  ".join(result["violations"]),
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
