"""Cross-space model transfer benchmark: never-seen kernel, borrowed model.

The acceptance experiment for structural-signature transfer (the fifth
warm-start tier): a ``ConfigStore`` is seeded with TP→PC_ops models
trained on SOURCE kernels only — the target kernel's space has never been
tuned, so all four exact-space ladder tiers miss by construction.  For
each seed, the held-out kernel is then tuned twice on the deterministic
synthetic backend (cost-model priced, virtual clock — bit-reproducible):

* **transferred** — ``transfer=True``: the store offers the most
  structurally similar same-kind model (counter-Jaccard × parameter
  overlap), rebound onto the target space through the shared-counter
  intersection, driving a distrust-and-verify ``TransferredWarmStart``.
* **cold** — ``transfer=False``: the legacy ladder alone, which misses,
  so the job falls back to seeded random search.

Convergence = completed trials until within ``WELL_FACTOR`` (1.1×) of the
target's exhaustive best (the paper's well-performing criterion),
censored at the budget.  Gates:

1. **Transfer wins** — the transferred median trials-to-well across seeds
   is strictly below the cold median.
2. **Exact hits unchanged** — when the store DOES hold the target's own
   model, the tuning trace with ``transfer=True`` is bit-identical to
   ``transfer=False`` (the fifth tier is invisible unless all four legacy
   tiers miss).

Writes ``BENCH_transfer.json``; exits non-zero when a gate is violated.

    PYTHONPATH=src python -m benchmarks.bench_transfer [--smoke]
        [--out BENCH_transfer.json] [--budget 40] [--seeds 9]
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
import time
from typing import Dict, List

import numpy as np

from repro.core import SPECS, record_space
from repro.fleet import FleetTuner, VirtualWorkerPool, job_from_registry
from repro.kernels.registry import BENCHMARKS
from repro.tuning import ConfigStore, TuningSession

SCHEMA = "repro.bench_transfer"
VERSION = 1

SOURCES = ("matmul", "transpose", "nbody", "attention", "coulomb")
TARGET = ("conv2d", "4096")
HW = "tpu_v5e"
WELL_FACTOR = 1.1


def _default_input(kernel: str) -> str:
    bm = BENCHMARKS[kernel]
    return next(k for k, v in bm.inputs.items() if v is bm.default_input)


def build_corpus(sources) -> ConfigStore:
    """Train one TP→PC_ops model per SOURCE kernel and publish it; the
    target kernel's space is deliberately absent."""
    store = ConfigStore()
    for kernel in sources:
        inp = _default_input(kernel)
        bm = BENCHMARKS[kernel]
        sp = bm.make_space()
        sess = TuningSession(sp, lambda c, _bm=bm, _i=inp:
                             _bm.workload_fn(c, _bm.inputs[_i]),
                             hw=SPECS[HW], seed=0)
        model = sess.train(kind="tree", sample="deliberate")
        store.save_model(sp.name, inp, HW, model, sp, kind="kernel")
    return store


def _clone(store: ConfigStore) -> ConfigStore:
    out = ConfigStore()
    out._models = dict(store._models)
    out._reindex_models()
    return out


def _run_target(store: ConfigStore, budget: int, seed: int,
                transfer: bool):
    pool = VirtualWorkerPool(workers=1)
    try:
        job = job_from_registry(TARGET[0], TARGET[1], HW, budget=budget,
                                seed=seed)
        ft = FleetTuner([job], pool, store=store, transfer=transfer,
                        publish_models=False)
        rep = ft.run()
    finally:
        pool.close()
    if ft.train_errors:
        raise RuntimeError(f"train errors: {ft.train_errors}")
    return rep.results[0]


def run_transfer(corpus: ConfigStore, budget: int, seeds: List[int],
                 threshold_s: float) -> Dict:
    rows = []
    for seed in seeds:
        tr = _run_target(_clone(corpus), budget, seed, transfer=True)
        cold = _run_target(_clone(corpus), budget, seed, transfer=False)
        if tr.searcher != "transfer_warm_start":
            raise RuntimeError(
                f"seed {seed}: transfer tier did not engage ({tr.searcher})")
        if cold.searcher != "random":
            raise RuntimeError(
                f"seed {seed}: cold run was not cold ({cold.searcher})")

        def t2w(r) -> int:
            v = r.trials_to_threshold(threshold_s)
            return int(v) if v is not None else int(budget)

        rows.append({
            "seed": seed,
            "transfer_from": tr.transfer_from,
            "similarity": tr.transfer_similarity,
            "transferred_trials_to_well": t2w(tr),
            "cold_trials_to_well": t2w(cold),
            "transferred_best_s": tr.best_runtime,
            "cold_best_s": cold.best_runtime,
        })
    t = [r["transferred_trials_to_well"] for r in rows]
    c = [r["cold_trials_to_well"] for r in rows]
    return {
        "target": "/".join(TARGET),
        "budget_per_run": budget,
        "well_factor": WELL_FACTOR,
        "well_threshold_s": threshold_s,
        "seeds": list(seeds),
        "runs": rows,
        "transferred_trials_to_well": t,
        "cold_trials_to_well": c,
        "transferred_median": float(np.median(t)),
        "cold_median": float(np.median(c)),
        "transferred_mean": float(np.mean(t)),
        "cold_mean": float(np.mean(c)),
        "median_ratio": float(np.median(t) / max(np.median(c), 1e-12)),
    }


def run_exact_golden(corpus: ConfigStore, budget: int,
                     seeds: List[int]) -> Dict:
    """Store holds the TARGET's own model: transfer on/off must produce
    bit-identical traces (the legacy ladder answers; tier five is idle)."""
    bm = BENCHMARKS[TARGET[0]]
    sp = bm.make_space()
    sess = TuningSession(sp, lambda c: bm.workload_fn(
        c, bm.inputs[TARGET[1]]), hw=SPECS[HW], seed=0)
    model = sess.train(kind="tree", sample="deliberate")
    base = _clone(corpus)
    base.save_model(sp.name, TARGET[1], HW, model, sp, kind="kernel")

    checked, identical = 0, True
    details = []
    for seed in seeds:
        on = _run_target(_clone(base), budget, seed, transfer=True)
        off = _run_target(_clone(base), budget, seed, transfer=False)
        same = (on.trace == off.trace and on.history == off.history
                and on.searcher == off.searcher == "warm_start"
                and on.transfer_from is None)
        identical = identical and same
        checked += 1
        details.append({"seed": seed, "identical": same,
                        "searcher_on": on.searcher,
                        "searcher_off": off.searcher})
    return {"runs_checked": checked, "bit_identical": identical,
            "details": details}


def run_benchmark(budget: int, n_seeds: int) -> Dict:
    corpus = build_corpus(SOURCES)
    bm = BENCHMARKS[TARGET[0]]
    rec = record_space(bm.make_space(),
                       lambda c: bm.workload_fn(c, bm.inputs[TARGET[1]]),
                       SPECS[HW])
    threshold = float(rec.best_runtime) * WELL_FACTOR
    seeds = list(range(n_seeds))
    transfer = run_transfer(corpus, budget, seeds, threshold)
    golden = run_exact_golden(corpus, budget, seeds[:max(3, n_seeds // 3)])
    summary = {
        "transferred_median_trials_to_well": transfer["transferred_median"],
        "cold_median_trials_to_well": transfer["cold_median"],
        "transfer_beats_cold":
            transfer["transferred_median"] < transfer["cold_median"],
        "exact_hits_bit_identical": golden["bit_identical"],
    }
    violations = []
    if not summary["transfer_beats_cold"]:
        violations.append(
            f"transferred median trials-to-well "
            f"{transfer['transferred_median']:.1f} is not below cold "
            f"median {transfer['cold_median']:.1f}")
    if not summary["exact_hits_bit_identical"]:
        violations.append("an exact-space warm start changed its trace "
                          "when transfer was enabled")
    return {
        "schema": SCHEMA,
        "version": VERSION,
        "generated": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "host": {"python": platform.python_version(),
                 "numpy": np.__version__,
                 "machine": platform.machine()},
        "workload": {
            "source_kernels": list(SOURCES),
            "target": "/".join(TARGET),
            "hardware": HW,
            "budget": budget,
            "n_seeds": n_seeds,
        },
        "transfer": transfer,
        "exact_golden": golden,
        "summary": summary,
        "violations": violations,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--out", default="BENCH_transfer.json")
    ap.add_argument("--budget", type=int, default=40,
                    help="per-run trial budget (also the censoring point)")
    ap.add_argument("--seeds", type=int, default=9,
                    help="number of tuning seeds per arm")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: fewer seeds, smaller budget")
    args = ap.parse_args(argv)

    budget, n_seeds = args.budget, args.seeds
    if args.smoke:
        budget, n_seeds = 30, 5

    result = run_benchmark(budget, n_seeds)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    s = result["summary"]
    t = result["transfer"]
    print(f"wrote {args.out}")
    print(f"never-seen {t['target']} on {HW}: transferred median "
          f"trials-to-well {s['transferred_median_trials_to_well']:.1f} vs "
          f"cold {s['cold_median_trials_to_well']:.1f} "
          f"(ratio {t['median_ratio']:.3f}; target < 1: "
          f"{'PASS' if s['transfer_beats_cold'] else 'FAIL'})")
    sims = sorted({r['transfer_from'] for r in t['runs']})
    print(f"  source artifact(s): {', '.join(sims)} "
          f"(similarity {t['runs'][0]['similarity']:.3f})")
    print(f"exact-hit golden (transfer on vs off, warm_start): "
          f"{'PASS' if s['exact_hits_bit_identical'] else 'FAIL'}")
    if result["violations"]:
        print("GATES VIOLATED:\n  " + "\n  ".join(result["violations"]),
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
