"""Search-overhead benchmark: scalar vs vectorized scoring engine.

The paper's Algorithm 1 re-scores the ENTIRE tuning space at every profiling
step, and its benchmarks range from 210 to 205,216 configurations — so
searcher overhead (not kernel measurement) dominates convergence time on
large spaces unless the score/select pipeline is array-native.  This
benchmark times the profile-searcher propose/observe loop on synthetic
recorded spaces of ~1k / ~20k / ~200k configurations, driving

* ``ScalarProfileBasedSearcher`` — the frozen pre-vectorization hot path
  (per-config ``model.predict`` + ``score_configuration`` dict loops), and
* ``ProfileBasedSearcher``       — the array-backed engine (whole-space
  ``predict_matrix`` + ``score_space``),

and writes ``BENCH_search_overhead.json`` so the perf trajectory is tracked
from commit to commit.  Both engines produce step-for-step identical traces
(tests/test_vectorized_golden.py) — this file measures only speed.

Usage (from the repo root):

    PYTHONPATH=src python -m benchmarks.bench_search_overhead \
        [--spaces 1k,20k,200k] [--models exact,tree] [--steps 60]
        [--repeats 3] [--out BENCH_search_overhead.json]
        [--min-speedup RATIO]   # exit 1 below this scalar/vectorized ratio
        [--ceiling-s SECONDS]   # exit 1 if any engine run exceeds it
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
import time
from typing import Dict, List

import numpy as np

from repro.core import (DecisionTreeModel, ExactCounterModel, ReplayEvaluator,
                        SPECS, TuningParameter, TuningSpace, run_search)
from repro.core._scalar_reference import ScalarProfileBasedSearcher
from repro.core.counters import PC_OPS, PC_STRESS, CounterSet
from repro.core.evaluate import RecordedSpace
from repro.core.searcher import ProfileBasedSearcher

SCHEMA = "repro.bench_search_overhead"
VERSION = 1

# Space definitions sized like the paper's regimes (GEMM-full is 205,216).
SPACE_PARAMS = {
    "1k": (  # 1024 configs
        ("bx", tuple(2**i for i in range(8))),
        ("by", tuple(2**i for i in range(8))),
        ("unroll", (1, 2, 4, 8)),
        ("vec", (0, 1)),
        ("prefetch", (0, 1)),
    ),
    "20k": (  # 16*16*10*2*2*2 = 20480 configs
        ("bx", tuple(2**i for i in range(16))),
        ("by", tuple(2**i for i in range(16))),
        ("unroll", tuple(2**i for i in range(10))),
        ("vec", (0, 1)),
        ("prefetch", (0, 1)),
        ("double_buffer", (0, 1)),
    ),
    "200k": (  # 36*36*10*2*2*2*2 = 207360 configs (paper max: 205,216)
        ("bx", tuple(2**i for i in range(36))),
        ("by", tuple(2**i for i in range(36))),
        ("unroll", tuple(2**i for i in range(10))),
        ("vec", (0, 1)),
        ("prefetch", (0, 1)),
        ("double_buffer", (0, 1)),
        ("swizzle", (0, 1)),
    ),
}


def synthetic_recorded(space_key: str, seed: int = 0) -> RecordedSpace:
    """A deterministic synthetic (runtime, counters) record.

    Ops counters are smooth functions of the feature matrix (so the TP→PC
    models have structure to learn); stress utilizations are derived from
    normalized ops; runtime rewards a planted optimum region.
    """
    rng = np.random.default_rng(seed)
    space = TuningSpace(
        [TuningParameter(n, v) for n, v in SPACE_PARAMS[space_key]],
        name=f"synthetic_{space_key}")
    fm = space.feature_matrix
    n = len(space)
    col = {p.name: j for j, p in enumerate(space.parameters)}
    bx = np.log2(np.maximum(fm[:, col["bx"]], 1.0)) + 1.0
    by = np.log2(np.maximum(fm[:, col["by"]], 1.0)) + 1.0
    unroll = fm[:, col["unroll"]]
    vec = fm[:, col["vec"]]

    ops = {
        "HBM_RD": 1e8 * (1.0 + 8.0 / bx) / (1.0 + vec),
        "HBM_WR": 2e7 * (1.0 + 4.0 / by),
        "VMEM_RD": 5e7 * bx * by / 16.0,
        "VMEM_WR": 2e7 * by,
        "SPILL_B": np.maximum(0.0, bx * by - 40.0) * 1e6,
        "MXU_FLOPS": np.full(n, 4e9),
        "VPU_OPS": 1e7 * unroll,
        "ISSUE_OPS": 1e7 * (bx + by + unroll),
        "GRID": 2.0 ** (16.0 - 0.5 * (bx + by)),
        "VMEM_WS": bx * by * 4096.0,
    }
    runtime = (
        1e-3
        + 2e-4 * np.abs(bx - 5.0)
        + 2e-4 * np.abs(by - 4.0)
        + 1e-4 * (1.0 - vec)
        + 5e-5 * np.abs(unroll - 4.0)
        + 1e-4 * rng.random(n)
    )
    # stress utilizations in [0, 1], loosely proportional to the ops mix
    def util(x):
        x = np.asarray(x, dtype=np.float64)
        return x / (x.max() or 1.0)

    stress = {
        "HBM_U": util(ops["HBM_RD"] + ops["HBM_WR"]),
        "VMEM_U": util(ops["VMEM_RD"] + ops["VMEM_WR"]),
        "CMEM_U": np.full(n, 0.05),
        "ICI_U": np.zeros(n),
        "MXU_U": util(ops["MXU_FLOPS"] / runtime),
        "VPU_U": util(ops["VPU_OPS"] / runtime),
        "TRANS_U": np.zeros(n),
        "ISSUE_U": util(ops["ISSUE_OPS"] / runtime),
        "CORE_E": np.minimum(1.0, ops["GRID"] / 256.0),
        "LANE_E": np.clip(1.0 - 2.0 / (bx * by), 0.1, 1.0),
        "VMEM_OCC": np.minimum(1.0, ops["VMEM_WS"] / 2**27),
    }
    op_names = list(ops)
    op_cols = np.stack([ops[k] for k in op_names], axis=1)
    st_names = list(stress)
    st_cols = np.stack([stress[k] for k in st_names], axis=1)
    counters: List[CounterSet] = []
    for i in range(n):
        counters.append(CounterSet(
            ops=dict(zip(op_names, op_cols[i].tolist())),
            stress=dict(zip(st_names, st_cols[i].tolist())),
            runtime=float(runtime[i]),
        ))
    return RecordedSpace(space=space, runtimes=runtime, counters=counters,
                         hw=SPECS["tpu_v5e"], input_tag=f"synth_{space_key}")


def _make_model(kind: str, rec: RecordedSpace, train_cap: int = 4096):
    if kind == "exact":
        return ExactCounterModel(rec.space, rec.ops_list())
    if kind == "tree":
        rng = np.random.default_rng(0)
        idxs = (np.arange(len(rec.space)) if len(rec.space) <= train_cap
                else rng.choice(len(rec.space), size=train_cap, replace=False))
        cfgs = [rec.space[int(i)] for i in idxs]
        ops = [rec.counters[int(i)].ops for i in idxs]
        return DecisionTreeModel(rec.space, cfgs, ops, rng=rng)
    raise ValueError(f"unknown model kind {kind!r}")


def _time_engine(factory, rec: RecordedSpace, steps: int, repeats: int
                 ) -> Dict[str, float]:
    totals = []
    for rep in range(repeats):
        searcher = factory(rep)
        ev = ReplayEvaluator(rec)
        t0 = time.perf_counter()
        run_search(searcher, ev, steps)
        totals.append(time.perf_counter() - t0)
        assert ev.steps == steps, (ev.steps, steps)
    # median is the steady-state number: with repeats >= 3 it excludes the
    # one cold repetition that builds the shared prediction matrix (with
    # repeats == 2 it averages cold and warm — cold_total_s tells them apart)
    median_total = float(np.median(totals))
    return {
        "total_s": median_total,
        "per_step_ms": median_total / steps * 1e3,
        "mean_total_s": float(np.mean(totals)),
        "cold_total_s": float(totals[0]),
    }


def run_benchmark(spaces, models, steps, repeats, ceiling_s=None,
                  min_speedup=None, seed=0) -> Dict:
    cores = SPECS["tpu_v5e"].cores
    rows = []
    violations = []
    for space_key in spaces:
        t0 = time.perf_counter()
        rec = synthetic_recorded(space_key, seed=seed)
        setup_s = time.perf_counter() - t0
        print(f"[{space_key}] {len(rec.space)} configs "
              f"(setup {setup_s:.1f}s)")
        for kind in models:
            model = _make_model(kind, rec)
            engines = {
                "scalar": lambda s: ScalarProfileBasedSearcher(
                    rec.space, model=model, cores=cores, seed=s),
                "vectorized": lambda s: ProfileBasedSearcher(
                    rec.space, model=model, cores=cores, seed=s),
            }
            row = {"space": space_key, "n_configs": len(rec.space),
                   "model": kind, "steps": steps, "repeats": repeats}
            for name, factory in engines.items():
                row[name] = _time_engine(factory, rec, steps, repeats)
                print(f"  {kind:6s} {name:11s} "
                      f"{row[name]['per_step_ms']:9.3f} ms/step "
                      f"(total {row[name]['total_s']:.3f}s)")
                if ceiling_s is not None and row[name]["total_s"] > ceiling_s:
                    violations.append(
                        f"{space_key}/{kind}/{name}: "
                        f"{row[name]['total_s']:.1f}s > {ceiling_s}s")
            row["speedup"] = (row["scalar"]["total_s"]
                              / row["vectorized"]["total_s"])
            print(f"  {kind:6s} speedup     {row['speedup']:9.1f}x")
            if min_speedup is not None and row["speedup"] < min_speedup:
                # the binding regression guard: the scalar/vectorized RATIO
                # is contention-independent, so a reintroduced O(n²) scan or
                # a silent fallback to the scalar path fails even on noisy
                # CI runners where an absolute wall clock cannot bind
                violations.append(
                    f"{space_key}/{kind}: speedup {row['speedup']:.1f}x "
                    f"< required {min_speedup:.1f}x")
            rows.append(row)
    speedup_20k = next((r["speedup"] for r in rows
                        if r["space"] == "20k" and r["model"] == "exact"),
                       None)
    return {
        "schema": SCHEMA,
        "version": VERSION,
        "generated": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "host": {"python": platform.python_version(),
                 "numpy": np.__version__,
                 "machine": platform.machine()},
        "rows": rows,
        "speedup_20k_exact": speedup_20k,
        "meets_20x_target": (speedup_20k is not None and speedup_20k >= 20.0),
        "violations": violations,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--spaces", default="1k,20k,200k")
    ap.add_argument("--models", default="exact,tree")
    ap.add_argument("--steps", type=int, default=60,
                    help="empirical-test budget per search")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--out", default="BENCH_search_overhead.json")
    ap.add_argument("--ceiling-s", type=float, default=None,
                    help="fail (exit 1) if any engine's median total "
                    "(total_s) exceeds this wall-clock — absolute backstop "
                    "against hangs")
    ap.add_argument("--min-speedup", type=float, default=None,
                    help="fail (exit 1) if any row's scalar/vectorized "
                    "speedup falls below this ratio — the binding, "
                    "contention-independent CI regression guard")
    args = ap.parse_args(argv)
    spaces = [s for s in args.spaces.split(",") if s]
    unknown = [s for s in spaces if s not in SPACE_PARAMS]
    if unknown:
        ap.error(f"unknown spaces {unknown}; choose from "
                 f"{sorted(SPACE_PARAMS)}")
    models = [m for m in args.models.split(",") if m]

    result = run_benchmark(spaces, models, args.steps, args.repeats,
                           ceiling_s=args.ceiling_s,
                           min_speedup=args.min_speedup)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"\nwrote {args.out}")
    if result["speedup_20k_exact"] is not None:
        print(f"20k exact-model speedup: "
              f"{result['speedup_20k_exact']:.1f}x "
              f"(target >= 20x: "
              f"{'PASS' if result['meets_20x_target'] else 'FAIL'})")
    if result["violations"]:
        print("PERF GUARD VIOLATED:\n  " + "\n  ".join(result["violations"]),
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
