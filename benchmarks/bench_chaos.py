"""Chaos harness: crash the tuning service mid-flight, prove nothing is lost.

Three fault-injection experiments over the deterministic virtual worker
pool (bit-reproducible trial counts), each asserting the crash-safety
contract of the journaled ``TuningDaemon``:

1. **Seeded mid-tuning kills** — 8 tenant requests (4 cold distinct
   keys over 2 kernels × 2 hardware keys, then 4 repeats of the same
   keys, all carrying idempotency keys) are driven to a seeded crash
   point, the daemon is abandoned without ANY shutdown courtesy (the
   in-process equivalent of SIGKILL: the write-ahead journal fsyncs per
   append, so durability cannot depend on a clean exit), and a fresh
   daemon recovers over the same journal + store.  Gates, per seeded
   crash point: every request resolves, and total empirical trials
   across both incarnations stay within ``--max-overhead`` (1.3×) of
   the crash-free run — interrupted jobs must RESUME from their
   journaled progress checkpoints, not retune from scratch.

2. **Socket drop + retried submit** — against a live socket daemon, the
   client's connection is severed mid-conversation; the reconnecting
   retry of an idempotency-keyed submit must dedupe onto the original
   request (no duplicate paid tuning run), and the handle must still
   resolve.

3. **Corrupted shard** — a shard of the corpus is bit-rotted on disk;
   reopening must quarantine it (``<path>.corrupt``) instead of
   crashing, recovery must rebuild the lost entries from the journal,
   and a repeat submit must be answered store-first with zero trials.

Writes ``BENCH_chaos.json``; exits non-zero when a gate is violated.

    PYTHONPATH=src python -m benchmarks.bench_chaos [--smoke]
        [--out BENCH_chaos.json] [--max-overhead 1.3]
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import random
import sys
import tempfile
import time
from typing import Dict, List

from repro.fleet import VirtualWorkerPool
from repro.service import (ServiceClient, ShardedConfigStore, TuningDaemon)
from repro.service import protocol as P

SCHEMA = "repro.bench_chaos"
VERSION = 1

KERNELS = (("matmul", "2048"), ("transpose", "8192"))
HW = ("tpu_v4", "tpu_v5e")
WORKERS = 4


# -- in-process harness (deterministic: no loop thread, no sockets) ------------
def _daemon(root: str, budget: int, recover: bool = False) -> TuningDaemon:
    d = TuningDaemon(
        VirtualWorkerPool(workers=WORKERS),
        ShardedConfigStore(os.path.join(root, "corpus"), n_shards=4),
        default_trial_budget=budget, in_flight=WORKERS,
        journal=os.path.join(root, "journal.jsonl"), recover=recover)
    d.tuner.begin()
    return d


def _tick(d: TuningDaemon) -> None:
    d._admit_pending()
    d.tuner.step(max_wait=0.01)
    d._meter()


def _submit_all(d: TuningDaemon, budget: int, seed: int) -> List[str]:
    """The 8-request tenant mix: 4 cold distinct keys + 4 repeats."""
    rids = []
    keys = [(k, inp, hw) for k, inp in KERNELS for hw in HW]
    for wave in ("cold", "repeat"):
        for i, (k, inp, hw) in enumerate(keys):
            r = d.handle(P.validate_request(dict(
                op="submit", kind="kernel", tenant=f"{wave}-{i}",
                kernel=k, input=inp, hardware=hw, budget=budget,
                seed=seed, idempotency_key=f"{wave}-{i}-{k}-{hw}")))
            assert r["ok"], r
            rids.append(r["request_id"])
    return rids


def _drive_to_resolution(d: TuningDaemon, rids: List[str],
                         max_iters: int = 5000) -> None:
    for _ in range(max_iters):
        if all(d._records[r].state in ("done", "cancelled") for r in rids):
            return
        _tick(d)
    raise AssertionError("daemon did not resolve all requests")


def _fleet_trials(d: TuningDaemon) -> int:
    return sum(js.account.steps for js in d.tuner._states)


def run_crash_recovery(root: str, budget: int, seed: int,
                       crash_points: int, max_overhead: float) -> Dict:
    """Seeded mid-tuning kills; every request must resolve cheaply."""
    # crash-free baseline: same 8 requests, same seed, no fault
    base_root = os.path.join(root, "baseline")
    os.makedirs(base_root)
    d = _daemon(base_root, budget)
    rids = _submit_all(d, budget, seed)
    _drive_to_resolution(d, rids)
    baseline_trials = _fleet_trials(d)
    baseline_states = [d._records[r].state for r in rids]
    d.journal.close()

    rng = random.Random(seed)
    runs = []
    for trial_i in range(crash_points):
        run_root = os.path.join(root, f"crash-{trial_i}")
        os.makedirs(run_root)
        d1 = _daemon(run_root, budget)
        rids = _submit_all(d1, budget, seed)
        # crash somewhere genuinely mid-tuning: after some progress,
        # before the cold wave could possibly finish
        crash_tick = rng.randint(2, max(3, budget * len(KERNELS) - 1))
        for _ in range(crash_tick):
            _tick(d1)
        trials_1 = _fleet_trials(d1)
        resolved_1 = sum(1 for r in rids
                         if d1._records[r].state in ("done", "cancelled"))
        d1.journal.close()       # the abandonment: no drain, no save

        d2 = _daemon(run_root, budget, recover=True)
        _drive_to_resolution(d2, rids)
        trials_2 = _fleet_trials(d2)
        total = trials_1 + trials_2
        states = {r: d2._records[r].state for r in rids}
        runs.append({
            "crash_tick": crash_tick,
            "trials_before_crash": trials_1,
            "resolved_before_crash": resolved_1,
            "trials_after_recovery": trials_2,
            "total_trials": total,
            "overhead_vs_crash_free": total / max(baseline_trials, 1),
            "all_resolved": all(s == "done" for s in states.values()),
            "recovery": {k: v for k, v in d2.recovery.items()
                         if k != "journal"},
        })
        d2.journal.close()
    worst = max(r["overhead_vs_crash_free"] for r in runs)
    return {
        "requests": 8,
        "budget_per_job": budget,
        "crash_points": crash_points,
        "baseline_trials": baseline_trials,
        "baseline_all_done": all(s == "done" for s in baseline_states),
        "runs": runs,
        "worst_overhead": worst,
        "all_requests_resolve": all(r["all_resolved"] for r in runs),
        "meets_overhead_target": worst <= max_overhead,
    }


def run_socket_drop(root: str, budget: int, seed: int) -> Dict:
    """Severed connection mid-conversation; keyed resubmit must dedupe."""
    d = TuningDaemon(
        VirtualWorkerPool(workers=WORKERS),
        ShardedConfigStore(os.path.join(root, "corpus"), n_shards=4),
        default_trial_budget=budget, in_flight=WORKERS,
        journal=os.path.join(root, "journal.jsonl"))
    d.start()
    try:
        c = ServiceClient(d.address, retries=3, backoff=0.01,
                          jitter_seed=seed)
        r1 = c.submit_kernel("drop", "matmul", "tpu_v4", input="2048",
                             budget=budget, seed=seed,
                             idempotency_key="drop-1")
        # sever the transport the rude way: the client's next call must
        # transparently reconnect
        c._sock.close()
        r2 = c.submit_kernel("drop", "matmul", "tpu_v4", input="2048",
                             budget=budget, seed=seed,
                             idempotency_key="drop-1")
        res = c.result(r1["request_id"], timeout=120)
        health = c.health()
        c.shutdown(drain=True)
        d.wait(timeout=120)
    finally:
        d.pool.close()
    return {
        "first_request": r1["request_id"],
        "retry_request": r2["request_id"],
        "retry_deduped": bool(r2.get("deduped")),
        "no_duplicate_run": r1["request_id"] == r2["request_id"],
        "request_resolved": res["state"] == "done",
        "trials": res["trials"],
        "daemon_was_healthy": bool(health["live"] and health["ready"]),
    }


def run_shard_corruption(root: str, budget: int, seed: int) -> Dict:
    """Bit-rot a shard; quarantine + journal-rebuild must cover it."""
    d = _daemon(root, budget)
    r = d.handle(P.validate_request(dict(
        op="submit", kind="kernel", tenant="victim", kernel="matmul",
        input="2048", hardware="tpu_v4", budget=budget, seed=seed)))
    rid = r["request_id"]
    _drive_to_resolution(d, [rid])
    d.store.save()
    d.journal.close()
    corpus = os.path.join(root, "corpus")
    shard_files = sorted(f for f in os.listdir(corpus)
                         if f.startswith("shard-") and f.endswith(".json")
                         and os.path.getsize(os.path.join(corpus, f)) > 0)
    for f in shard_files:        # rot every populated shard
        with open(os.path.join(corpus, f), "r+") as fh:
            fh.seek(max(0, os.path.getsize(os.path.join(corpus, f)) // 2))
            fh.write("\x00GARBAGE")

    d2 = _daemon(root, budget, recover=True)
    quarantined = list(d2.store.quarantined)
    repeat = d2.handle(P.validate_request(dict(
        op="submit", kind="kernel", tenant="after", kernel="matmul",
        input="2048", hardware="tpu_v4", budget=budget, seed=seed)))
    d2.journal.close()
    return {
        "shards_corrupted": len(shard_files),
        "quarantined_files": len(quarantined),
        "corrupt_markers_on_disk": sum(
            1 for f in os.listdir(corpus) if ".corrupt" in f),
        "repaired_entries": d2.recovery["repaired_entries"],
        "repeat_state": repeat.get("state"),
        "repeat_trials": repeat.get("trials"),
        "repeat_answered_from_store": (repeat.get("state") == "done"
                                       and repeat.get("trials") == 0),
    }


def run_benchmark(budget: int, seed: int, crash_points: int,
                  max_overhead: float) -> Dict:
    t0 = time.time()
    with tempfile.TemporaryDirectory(prefix="bench_chaos.") as root:
        crash = run_crash_recovery(os.path.join(root, "crash"), budget,
                                   seed, crash_points, max_overhead)
        drop = run_socket_drop(os.path.join(root, "drop"), budget, seed)
        rot = run_shard_corruption(os.path.join(root, "rot"), budget, seed)

    violations = []
    if not crash["all_requests_resolve"]:
        violations.append("a request failed to resolve after recovery")
    if not crash["meets_overhead_target"]:
        violations.append(
            f"recovery overhead {crash['worst_overhead']:.3f}x exceeds "
            f"{max_overhead}x crash-free trials")
    if not (drop["retry_deduped"] and drop["no_duplicate_run"]):
        violations.append("socket-drop resubmit was not deduped")
    if not drop["request_resolved"]:
        violations.append("socket-drop request did not resolve")
    if not rot["repeat_answered_from_store"]:
        violations.append("corrupted shard was not rebuilt from journal")
    if rot["quarantined_files"] < 1:
        violations.append("corrupted shard was not quarantined")

    return {
        "schema": SCHEMA,
        "version": VERSION,
        "config": {"budget": budget, "seed": seed,
                   "crash_points": crash_points,
                   "max_overhead": max_overhead, "workers": WORKERS},
        "env": {"python": platform.python_version(),
                "platform": platform.platform()},
        "crash_recovery": crash,
        "socket_drop": drop,
        "shard_corruption": rot,
        "summary": {
            "all_requests_resolve": crash["all_requests_resolve"],
            "worst_overhead": crash["worst_overhead"],
            "meets_overhead_target": crash["meets_overhead_target"],
            "socket_drop_deduped": drop["retry_deduped"],
            "shard_rebuilt_from_journal":
                rot["repeat_answered_from_store"],
        },
        "violations": violations,
        "wall_s": round(time.time() - t0, 3),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--out", default="BENCH_chaos.json")
    ap.add_argument("--budget", type=int, default=12,
                    help="per-request trial budget")
    ap.add_argument("--seed", type=int, default=13)
    ap.add_argument("--crash-points", type=int, default=5,
                    help="seeded mid-tuning kill points to try")
    ap.add_argument("--max-overhead", type=float, default=1.3,
                    help="max total-trials ratio vs the crash-free run")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: smaller budgets, fewer crash points")
    args = ap.parse_args(argv)

    budget = 6 if args.smoke else args.budget
    crash_points = 3 if args.smoke else args.crash_points
    result = run_benchmark(budget, args.seed, crash_points,
                           args.max_overhead)

    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    s = result["summary"]
    print(f"wrote {args.out}")
    print(f"crash recovery over {crash_points} seeded kill points: "
          f"all resolve {'PASS' if s['all_requests_resolve'] else 'FAIL'}, "
          f"worst overhead {s['worst_overhead']:.3f}x "
          f"(target <= {args.max_overhead}x: "
          f"{'PASS' if s['meets_overhead_target'] else 'FAIL'})")
    print(f"socket drop: dedupe "
          f"{'PASS' if s['socket_drop_deduped'] else 'FAIL'}")
    print(f"shard corruption: journal rebuild "
          f"{'PASS' if s['shard_rebuilt_from_journal'] else 'FAIL'}")
    if result["violations"]:
        print("TARGETS VIOLATED:\n  " + "\n  ".join(result["violations"]),
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
