"""Hot-path throughput benchmark: group commit, off-loop training, delta saves.

Three experiments over the optimizations that moved durability and model
training off the service hot path:

1. **Journal storm** — one client process drives 8 tenant connections,
   each with a sliding window of pipelined store-first submits, against
   one ``TuningDaemon`` (real localhost sockets), so every ack costs
   exactly two journal records and zero trials.  Each (mode, rep) runs
   in a fresh subprocess; the same storm runs under each durability
   mode: ``always`` (per-record inline fsync — the old behavior),
   ``batch`` (group commit: acks still wait for the fsync covering their
   records, one flush covers a burst), and ``off`` (flush only).  Gates:
   batch ≥ ``--min-journal-speedup`` (3×) the submit-to-ack throughput
   of always, and the batch journal is COMPLETE — every acked request's
   submit+done records are on disk after the storm, on every rep.

2. **Trainer offload** — a ``ThreadWorkerPool`` fleet over six jobs with
   six DISTINCT search spaces and blocking measurement closures, run
   from a cold store so every finalize trains and publishes a real
   model: ``train_async=False`` (model training stalls the fleet loop,
   the old behavior) vs ``train_async=True`` (background trainer
   thread).  Budget multipliers stagger completion so the expensive
   trainers finish while cheap-training jobs still have trials left to
   overlap.  Gates: the async fleet's makespan beats sync by
   ``--min-trainer-speedup`` and both runs produce IDENTICAL per-job
   results (the offload must not change what gets tuned, only when the
   loop blocks).

3. **Store saves** — one ``ConfigStore`` with a populated corpus: a
   forced full save (read-back + merge + rewrite, the old every-save
   cost) vs a dirty save after one ``put`` (own-write fast path: the
   stat token proves the file is ours, no read-back) vs a clean save
   (pure no-op).  Gates: no-op ≥ ``--min-noop-speedup`` (10×) and the
   dirty fast path ≥ ``--min-dirty-speedup`` (1.3×) vs the forced full
   save.

Writes ``BENCH_service_throughput.json``; exits non-zero on violation.

    PYTHONPATH=src python -m benchmarks.bench_service_throughput [--smoke]
        [--out BENCH_service_throughput.json]
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import tempfile
import time
from typing import Dict, List

import numpy as np

from repro.fleet import (FleetTuner, ThreadWorkerPool, VirtualWorkerPool,
                         job_from_registry)
from repro.service import ServiceClient, ShardedConfigStore, TuningDaemon
from repro.service.journal import MODES, RequestJournal
from repro.tuning import ConfigStore

SCHEMA = "repro.bench_service_throughput"
VERSION = 1

STORM_KEYS = (("matmul", "2048", "tpu_v4"), ("transpose", "8192", "tpu_v4"),
              ("conv2d", "4096", "tpu_v5e"), ("matmul", "128", "tpu_v5e"))
STORM_TENANTS = 8
STORM_DEPTH = 4     # in-flight submits per tenant (a suite, not one job)

# Six jobs over six DISTINCT search spaces — each publishes its own model
# key, so no job's searcher binding ever defers on another's pending
# publish and every finalize performs real model training (cold store).
# Budget multipliers stagger completion: the expensive trainers (coulomb
# ~180ms, conv2d/matmul ~90ms) finish their trials early, so their
# training either stalls dispatch (sync) or overlaps the cheap trainers'
# long trial tails (async).
TRAIN_KERNELS = (("coulomb", "small_grid", "tpu_v4", 1),
                 ("conv2d", "4096", "tpu_v4", 1),
                 ("matmul", "2048", "tpu_v5e", 1),
                 ("nbody", "16k", "tpu_v5e", 2),
                 ("attention", "default", "tpu_v4", 3),
                 ("transpose", "8192", "tpu_v5e", 3))
WORKERS = 4


def _pctile(xs: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs, dtype=np.float64), q))


# ---------------------------------------------------------------- journal

def _storm_daemon(root: str, mode: str) -> TuningDaemon:
    """Daemon with a pre-populated store (every storm submit resolves
    store-first: zero trials, two journal records) and a journal in the
    requested durability mode."""
    store = ShardedConfigStore(os.path.join(root, "corpus"), n_shards=4)
    for k, inp, hw in STORM_KEYS:
        job = job_from_registry(k, inp, hw)
        store.put(job.space.name, job.bucket, job.hardware_key,
                  config=dict(job.space[0]), runtime=1.0, trials=8,
                  kind=job.kind)
    store.save()
    journal = RequestJournal(os.path.join(root, "journal.jsonl"), mode=mode)
    d = TuningDaemon(VirtualWorkerPool(workers=WORKERS), store,
                     journal=journal, in_flight=WORKERS)
    d.start()
    return d


def _storm_child(argv: List[str]) -> int:
    """The storm client process: 8 tenant connections, each keeping a
    window of ``STORM_DEPTH`` submits in flight (a tenant tuning a
    kernel suite submits a batch, not one job at a time).  Reports
    per-ack latencies as JSON on stdout.

    Runs out-of-process so the client's JSON/socket work does not share
    the daemon's GIL, and as ONE process rather than one per tenant: on
    a small host N client processes timeslice against the daemon, which
    both steals server CPU and staggers arrivals that 8 genuinely
    parallel clients would deliver simultaneously — understating every
    mode and artificially starving the group commit of coalescable
    records.  The sliding windows preserve the storm's defining
    property (8 concurrent tenants under sustained submit pressure)
    without the scheduler noise.  The submit lines are pre-encoded over
    bare sockets for the same reason; latency is still full
    submit-to-ack: send, wait, parse.
    """
    import socket
    from collections import deque

    from repro.service import protocol as P

    host, port, tenants, per_tenant, seed, start_at = (
        argv[0], int(argv[1]), int(argv[2]), int(argv[3]), int(argv[4]),
        float(argv[5]))
    out = {"lat": [], "rids": [], "errors": [], "start": 0.0, "end": 0.0}
    loads, perf = json.loads, time.perf_counter
    payloads = [[P.encode({"op": "submit", "kind": "kernel",
                           "tenant": f"t{i}", "kernel": k, "input": inp,
                           "hardware": hw, "budget": 4, "seed": seed})
                 for k, inp, hw in STORM_KEYS] for i in range(tenants)]

    def read_ack(i, f, sent_at):
        r = loads(f.readline())
        out["lat"].append(perf() - sent_at.popleft())
        if not r.get("ok") or r.get("state") != "done":
            out["errors"].append(f"t{i}: bad ack {r!r}")
        else:
            out["rids"].append(r["request_id"])

    conns = []
    try:
        try:
            for _ in range(tenants):
                s = socket.create_connection((host, port), timeout=60)
                s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                conns.append((s, s.makefile("rb")))
            nk = len(STORM_KEYS)
            for n in range(2):  # warm every connection + the daemon path
                for i, (s, _) in enumerate(conns):
                    s.sendall(payloads[i][(i + n) % nk])
                for _, f in conns:
                    loads(f.readline())
            while time.time() < start_at:
                time.sleep(min(0.005, max(start_at - time.time(), 0)))
            out["start"] = time.time()
            sent = [deque() for _ in range(tenants)]
            for n in range(per_tenant):
                for i, (s, f) in enumerate(conns):
                    if len(sent[i]) >= STORM_DEPTH:
                        read_ack(i, f, sent[i])
                    sent[i].append(perf())
                    s.sendall(payloads[i][(i + n) % nk])
            for i, (_, f) in enumerate(conns):
                while sent[i]:
                    read_ack(i, f, sent[i])
            out["end"] = time.time()
        finally:
            for s, f in conns:
                f.close()
                s.close()
    except Exception as exc:
        out["errors"].append(f"storm: {exc!r}")
    print(json.dumps(out))
    return 0


def _storm_once(root: str, mode: str, per_tenant: int, seed: int) -> Dict:
    """8 pipelined tenant connections × ``per_tenant`` store-first
    submits, driven by one out-of-process storm client."""
    import subprocess

    d = _storm_daemon(root, mode)
    host, port = d.address
    env = dict(os.environ)
    src = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(sys.modules["repro"].__file__))))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    start_at = time.time() + 3.0   # lead time for child interpreter spinup
    p = subprocess.Popen(
        [sys.executable, "-m", "benchmarks.bench_service_throughput",
         "--storm-child", host, str(port), str(STORM_TENANTS),
         str(per_tenant), str(seed), repr(start_at)],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    reports, errors = [], []
    stdout, stderr = p.communicate(timeout=300)
    if p.returncode != 0 or not stdout.strip():
        errors.append(f"storm client died: {stderr.decode()[-300:]}")
    else:
        rep = json.loads(stdout)
        reports.append(rep)
        errors.extend(rep["errors"])
    wall = (max(r["end"] for r in reports)
            - min(r["start"] for r in reports)) if reports else 1.0
    lat = [per["lat"] for per in reports]
    acked = [per["rids"] for per in reports]
    with ServiceClient(d.address) as c:
        jstats = c.stats()["journal"]
        c.shutdown(drain=True)
    d.wait(timeout=120)
    d.pool.close()
    d.journal.close()

    # completeness: every acked request's EV_SUBMIT and EV_DONE must be
    # on disk after the storm (acks never outran durability)
    on_disk: Dict[str, set] = {}
    with open(os.path.join(root, "journal.jsonl")) as f:
        for line in f:
            rec = json.loads(line)
            if rec.get("rid"):
                on_disk.setdefault(rec["rid"], set()).add(rec["ev"])
    rids = [rid for per in acked for rid in per]
    missing = [rid for rid in rids
               if not {"submit", "done"} <= on_disk.get(rid, set())]
    all_lat = [x for per in lat for x in per]
    return {
        "mode": mode,
        "acks": len(all_lat),
        "wall_s": wall,
        "throughput_rps": len(all_lat) / max(wall, 1e-12),
        "ack_p50_ms": _pctile(all_lat, 50) * 1e3 if all_lat else None,
        "ack_p99_ms": _pctile(all_lat, 99) * 1e3 if all_lat else None,
        "journal": {k: jstats[k] for k in
                    ("mode", "records", "bytes", "commits", "last_batch",
                     "max_batch", "pending") if k in jstats},
        "complete": not missing and not errors,
        "missing_records": missing[:5],
        "errors": errors[:5],
    }


def _storm_isolated(root: str, mode: str, per_tenant: int,
                    seed: int) -> Dict:
    """One ``_storm_once`` in a fresh daemon process: long-lived
    benchmark processes accumulate heap/allocator state that skews later
    runs, so every (mode, rep) measurement starts from an identical
    interpreter."""
    import subprocess

    env = dict(os.environ)
    src = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(sys.modules["repro"].__file__))))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    p = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_service_throughput",
         "--storm-once", root, mode, str(per_tenant), str(seed)],
        capture_output=True, env=env, timeout=600,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    if p.returncode != 0 or not p.stdout.strip():
        return {"mode": mode, "acks": 0, "wall_s": 1.0,
                "throughput_rps": 0.0, "ack_p50_ms": None,
                "ack_p99_ms": None, "journal": {}, "complete": False,
                "missing_records": [],
                "errors": [f"storm daemon died: {p.stderr.decode()[-300:]}"]}
    return json.loads(p.stdout)


def run_journal_storm(root: str, per_tenant: int, seed: int,
                      min_speedup: float, reps: int = 3) -> Dict:
    """Interleaved repetitions — rep 0 of every mode, then rep 1, ... —
    so slow drift in the host penalizes all modes alike; the gate reads
    each mode's best rep (the run least disturbed by scheduler noise),
    while completeness must hold on EVERY rep."""
    runs: Dict[str, List[Dict]] = {m: [] for m in MODES}
    for rep in range(reps):
        for m in MODES:
            runs[m].append(_storm_isolated(
                os.path.join(root, f"{m}{rep}"), m, per_tenant,
                seed + rep))
    by_mode = {m: max(runs[m], key=lambda r: r["throughput_rps"])
               for m in MODES}
    for m in MODES:
        by_mode[m]["complete"] = all(r["complete"] for r in runs[m])
        by_mode[m]["rep_throughputs_rps"] = [
            r["throughput_rps"] for r in runs[m]]
    thr = {m: by_mode[m]["throughput_rps"] for m in MODES}
    speedup = thr["batch"] / max(thr["always"], 1e-12)
    b = by_mode["batch"]["journal"]
    return {
        "tenants": STORM_TENANTS,
        "submits_per_tenant": per_tenant,
        "reps": reps,
        "keys": [list(k) for k in STORM_KEYS],
        "modes": by_mode,
        "batch_vs_always_speedup": speedup,
        "off_vs_always_speedup": thr["off"] / max(thr["always"], 1e-12),
        "batch_records_per_commit": (b.get("records", 0)
                                     / max(b.get("commits", 1), 1)),
        "meets_speedup_target": speedup >= min_speedup,
        "batch_journal_complete": by_mode["batch"]["complete"],
    }


# ---------------------------------------------------------------- trainer

def _train_jobs(budget: int, seed: int, delay_s: float):
    """Six distinct-space model keys with a blocking, deterministic
    measurement closure — real wall-clock trials on the thread pool,
    identical runtimes regardless of scheduling."""
    jobs = []
    for k, inp, hw, mult in TRAIN_KERNELS:
        job = job_from_registry(k, inp, hw, budget=budget * mult,
                                seed=seed, searcher="random")

        def eval_fn(index, profile, _n=len(job.space)):
            time.sleep(delay_s)
            return 1.0 + (index % _n) / _n, None, delay_s

        job.eval_fn = eval_fn
        jobs.append(job)
    return jobs


def _train_once(root: str, budget: int, seed: int, delay_s: float,
                train_async: bool) -> Dict:
    """One cold-store fleet pass: every job trains and publishes its
    model at finalize (no key exists yet), work ``train_async=False``
    performs inline on the scheduling loop — stalling dispatch while
    other jobs' trials sleep on the pool — and ``train_async=True``
    overlaps from the trainer thread."""
    store = ShardedConfigStore(os.path.join(root, "corpus"), n_shards=4)
    jobs = _train_jobs(budget, seed, delay_s)
    pool = ThreadWorkerPool(workers=WORKERS)
    try:
        tuner = FleetTuner(jobs, pool, store=store, in_flight=len(jobs),
                           train_async=train_async)
        t0 = time.perf_counter()
        rep = tuner.run()
        wall = time.perf_counter() - t0
    finally:
        pool.close()
    models = sum(1 for _ in store.model_keys())
    return {
        "train_async": train_async,
        "wall_s": wall,
        "jobs": len(rep.results),
        "models_published": models,
        "train_errors": list(getattr(tuner, "train_errors", [])),
        "results": sorted((r.job, r.trials, round(r.best_runtime, 9))
                          for r in rep.results),
    }


def run_trainer_offload(root: str, budget: int, seed: int, delay_s: float,
                        min_speedup: float) -> Dict:
    sync = _train_once(os.path.join(root, "sync"), budget, seed, delay_s,
                       train_async=False)
    off = _train_once(os.path.join(root, "async"), budget, seed, delay_s,
                      train_async=True)
    speedup = sync["wall_s"] / max(off["wall_s"], 1e-12)
    return {
        "budget_per_job": budget,
        "trial_delay_ms": delay_s * 1e3,
        "model_keys": len(TRAIN_KERNELS),
        "sync": sync,
        "async": off,
        "makespan_speedup": speedup,
        "meets_speedup_target": speedup >= min_speedup,
        "results_identical": sync["results"] == off["results"],
        "all_models_published": (off["models_published"]
                                 == len(TRAIN_KERNELS)
                                 and not off["train_errors"]),
    }


# ---------------------------------------------------------------- store

def run_store_saves(root: str, n_entries: int, reps: int,
                    min_noop_speedup: float,
                    min_dirty_speedup: float) -> Dict:
    os.makedirs(root, exist_ok=True)
    path = os.path.join(root, "store.json")
    store = ConfigStore(path)
    store.autosave = False
    for i in range(n_entries):
        store.put(f"sp{i % 16}", f"b{i}", "tpu_v4",
                  config={"BM": 64, "BN": 128, "i": i},
                  runtime=1.0 + i * 1e-3, trials=8)
    store.save()

    def timed_once(fn):
        t0 = time.perf_counter()
        fn()
        return time.perf_counter() - t0

    i = [0]

    def dirty_save():
        i[0] += 1
        store.put("sp0", "b0", "tpu_v4",
                  config={"BM": 64, "BN": 128, "i": i[0]},
                  runtime=0.5 - i[0] * 1e-6, trials=8)
        store.save()

    # Interleaved rounds, best-of per category: a CPU-pressure or fsync
    # spike on a shared runner then lands on one sample of one category
    # instead of poisoning a whole back-to-back block, and min measures
    # the cost floor the fast path actually removes.
    fulls, dirties = [], []
    for _ in range(reps):
        fulls.append(timed_once(lambda: store.save(force=True)))
        dirties.append(timed_once(dirty_save))
    t_full = min(fulls)
    t_dirty = min(dirties)

    n_noop = max(reps * 20, 100)
    t0 = time.perf_counter()
    for _ in range(n_noop):
        store.save()
    t_noop = (time.perf_counter() - t0) / n_noop

    # round-trip sanity: what is on disk equals what is in memory
    reread = ConfigStore(path)
    equivalent = reread.to_dict()["entries"] == store.to_dict()["entries"]
    noop_speedup = t_full / max(t_noop, 1e-12)
    dirty_speedup = t_full / max(t_dirty, 1e-12)
    return {
        "entries": n_entries,
        "reps": reps,
        "full_save_ms": t_full * 1e3,
        "dirty_save_ms": t_dirty * 1e3,
        "noop_save_ms": t_noop * 1e3,
        "noop_speedup": noop_speedup,
        "dirty_speedup": dirty_speedup,
        "save_stats": dict(store.save_stats),
        "disk_matches_memory": equivalent,
        "meets_noop_target": noop_speedup >= min_noop_speedup,
        "meets_dirty_target": dirty_speedup >= min_dirty_speedup,
    }


# ---------------------------------------------------------------- driver

def run_benchmark(smoke: bool, seed: int, min_journal: float,
                  min_trainer: float, min_noop: float,
                  min_dirty: float) -> Dict:
    per_tenant = 60 if smoke else 200
    budget = 8 if smoke else 12
    delay_s = 0.02 if smoke else 0.025
    n_entries = 500 if smoke else 800
    reps = 6 if smoke else 10
    with tempfile.TemporaryDirectory() as td:
        journal = run_journal_storm(os.path.join(td, "j"), per_tenant,
                                    seed, min_journal,
                                    reps=2 if smoke else 3)
        trainer = run_trainer_offload(os.path.join(td, "t"), budget, seed,
                                      delay_s, min_trainer)
        saves = run_store_saves(os.path.join(td, "s"), n_entries, reps,
                                min_noop, min_dirty)
    summary = {
        "journal_speedup": journal["batch_vs_always_speedup"],
        "journal_speedup_ok": journal["meets_speedup_target"],
        "journal_complete": journal["batch_journal_complete"],
        "trainer_speedup": trainer["makespan_speedup"],
        "trainer_speedup_ok": trainer["meets_speedup_target"],
        "trainer_deterministic": trainer["results_identical"],
        "trainer_published_all": trainer["all_models_published"],
        "noop_speedup": saves["noop_speedup"],
        "noop_speedup_ok": saves["meets_noop_target"],
        "dirty_speedup": saves["dirty_speedup"],
        "dirty_speedup_ok": saves["meets_dirty_target"],
        "store_roundtrip_ok": saves["disk_matches_memory"],
    }
    violations: List[str] = []
    if not summary["journal_speedup_ok"]:
        violations.append(
            f"group commit speedup {summary['journal_speedup']:.2f}x "
            f"< {min_journal}x (batch vs per-record fsync)")
    if not summary["journal_complete"]:
        violations.append("batch-mode journal lost acked records "
                          "(ack outran durability)")
    if not summary["trainer_speedup_ok"]:
        violations.append(
            f"trainer offload speedup {summary['trainer_speedup']:.2f}x "
            f"< {min_trainer}x")
    if not summary["trainer_deterministic"]:
        violations.append("async training changed tuning results")
    if not summary["trainer_published_all"]:
        violations.append("async training dropped model publishes")
    if not summary["noop_speedup_ok"]:
        violations.append(
            f"clean-save no-op speedup {summary['noop_speedup']:.1f}x "
            f"< {min_noop}x")
    if not summary["dirty_speedup_ok"]:
        violations.append(
            f"dirty-save fast path speedup "
            f"{summary['dirty_speedup']:.2f}x < {min_dirty}x")
    if not summary["store_roundtrip_ok"]:
        violations.append("delta/fast-path save diverged from memory")
    return {
        "schema": SCHEMA,
        "version": VERSION,
        "generated": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "host": {"python": platform.python_version(),
                 "numpy": np.__version__,
                 "machine": platform.machine()},
        "workload": {"smoke": smoke, "seed": seed,
                     "storm_tenants": STORM_TENANTS,
                     "storm_submits_per_tenant": per_tenant,
                     "trainer_budget": budget,
                     "store_entries": n_entries},
        "targets": {"min_journal_speedup": min_journal,
                    "min_trainer_speedup": min_trainer,
                    "min_noop_speedup": min_noop,
                    "min_dirty_speedup": min_dirty},
        "journal_storm": journal,
        "trainer_offload": trainer,
        "store_saves": saves,
        "summary": summary,
        "violations": violations,
    }


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if argv and argv[0] == "--storm-child":
        return _storm_child(argv[1:])
    if argv and argv[0] == "--storm-once":
        root, mode, per_tenant, seed = argv[1:5]
        print(json.dumps(_storm_once(root, mode, int(per_tenant),
                                     int(seed))))
        return 0
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--out", default="BENCH_service_throughput.json")
    ap.add_argument("--seed", type=int, default=11)
    ap.add_argument("--min-journal-speedup", type=float, default=3.0,
                    help="required batch/always submit-to-ack throughput")
    ap.add_argument("--min-trainer-speedup", type=float, default=None,
                    help="required sync/async fleet makespan ratio "
                    "(default 1.15; --smoke uses 1.1 for headroom on "
                    "noisy shared runners)")
    ap.add_argument("--min-noop-speedup", type=float, default=10.0)
    ap.add_argument("--min-dirty-speedup", type=float, default=1.3)
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: smaller storm and corpus")
    args = ap.parse_args(argv)

    min_trainer = args.min_trainer_speedup
    if min_trainer is None:
        min_trainer = 1.1 if args.smoke else 1.15
    result = run_benchmark(args.smoke, args.seed,
                           args.min_journal_speedup, min_trainer,
                           args.min_noop_speedup, args.min_dirty_speedup)

    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    s = result["summary"]
    j = result["journal_storm"]
    print(f"wrote {args.out}")
    print(f"journal storm ({j['tenants']} tenants x "
          f"{j['submits_per_tenant']}): batch "
          f"{j['modes']['batch']['throughput_rps']:.0f} rps vs always "
          f"{j['modes']['always']['throughput_rps']:.0f} rps = "
          f"{s['journal_speedup']:.2f}x "
          f"({'PASS' if s['journal_speedup_ok'] else 'FAIL'}), "
          f"complete {'PASS' if s['journal_complete'] else 'FAIL'}")
    print(f"trainer offload: {s['trainer_speedup']:.2f}x makespan "
          f"({'PASS' if s['trainer_speedup_ok'] else 'FAIL'}), "
          f"deterministic "
          f"{'PASS' if s['trainer_deterministic'] else 'FAIL'}")
    print(f"store saves: no-op {s['noop_speedup']:.0f}x "
          f"({'PASS' if s['noop_speedup_ok'] else 'FAIL'}), dirty "
          f"{s['dirty_speedup']:.2f}x "
          f"({'PASS' if s['dirty_speedup_ok'] else 'FAIL'})")
    if result["violations"]:
        print("TARGETS VIOLATED:\n  " + "\n  ".join(result["violations"]),
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
