"""Tuning-as-a-service benchmark: multi-tenant daemon vs single-job fleet.

Four experiments against a live ``TuningDaemon`` (real localhost socket,
JSON-lines protocol) over the deterministic virtual worker pool, so every
trial count and worker-second is bit-reproducible:

1. **Multi-tenant amortization** — ≥ 8 tenants share one daemon over
   2 kernels × 2 hardware keys: 4 *cold* tenants tune distinct keys
   concurrently, then 4 *repeat* tenants ask for the same keys.  Gates:
   every repeat resolves store-only with ZERO trials, and the daemon's
   fleet utilization (busy worker-seconds / (makespan × workers)) under
   the mixed tenant load stays within ``--max-util-ratio`` (1.3×) of a
   single ``FleetTuner`` run given the same four cold jobs directly.

2. **Budget enforcement** — a tenant with a near-zero worker-seconds
   budget overspends on its first job; its queued work is parked, its
   next submit bounces with ``budget_exhausted``, and a solvent tenant
   sharing the daemon still completes its full budget, unaffected.

3. **Serve-path routing** — an ``OnlineAutotuner`` on the synthetic
   serving backend with ``service=`` set routes its drift retune through
   the daemon (zero live trials on the engine) and adopts the result
   into its local store; pointed at a dead port it falls back to
   in-process live tuning.

4. **Drain** — ``shutdown(drain=True)`` mid-tuning: in-flight trials are
   collected and billed, the unfinished request resolves ``cancelled``
   with partial progress, and the daemon exits cleanly.

Writes ``BENCH_service.json``; exits non-zero when a target is violated.

    PYTHONPATH=src python -m benchmarks.bench_service [--smoke]
        [--out BENCH_service.json] [--max-util-ratio 1.3]
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import tempfile
import time
from typing import Dict, List

import numpy as np

from repro.fleet import FleetTuner, VirtualWorkerPool, job_from_registry
from repro.service import ServiceClient, ShardedConfigStore, TuningDaemon
from repro.service.client import ServiceError
from repro.service.tenants import TenantManager

SCHEMA = "repro.bench_service"
VERSION = 1

KERNELS = (("matmul", "2048"), ("transpose", "8192"))
HW = ("tpu_v4", "tpu_v5e")
WORKERS = 4


def _daemon(root: str, budget: int, **kw) -> TuningDaemon:
    d = TuningDaemon(VirtualWorkerPool(workers=WORKERS),
                     ShardedConfigStore(os.path.join(root, "corpus"),
                                        n_shards=4),
                     default_trial_budget=budget, in_flight=WORKERS, **kw)
    d.start()
    return d


def run_multi_tenant(root: str, budget: int, seed: int,
                     max_util_ratio: float) -> Dict:
    """8 tenants, 4 keys: cold wave tunes, repeat wave pays zero trials."""
    keys = [(k, inp, hw) for k, inp in KERNELS for hw in HW]
    d = _daemon(root, budget)
    try:
        with ServiceClient(d.address) as c:
            cold = {}
            for i, (k, inp, hw) in enumerate(keys):
                r = c.submit_kernel(f"cold-{i}", k, hw, input=inp,
                                    budget=budget, seed=seed)
                cold[r["request_id"]] = (f"cold-{i}", k, inp, hw)
            cold_results = {rid: c.result(rid, timeout=300)
                            for rid in cold}
            fleet = c.stats()["fleet"]
            repeat_results = []
            for i, (k, inp, hw) in enumerate(keys):
                r = c.submit_kernel(f"repeat-{i}", k, hw, input=inp,
                                    budget=budget, seed=seed)
                repeat_results.append(r)
            stats = c.stats()
            c.shutdown(drain=True)
        d.wait(timeout=120)
    finally:
        d.pool.close()

    service_util = fleet["utilization"]
    # baseline: the same four cold jobs handed straight to one FleetTuner
    base_jobs = [job_from_registry(k, inp, hw, budget=budget, seed=seed)
                 for k, inp, hw in keys]
    base_store = ShardedConfigStore(os.path.join(root, "base_corpus"),
                                    n_shards=4)
    base_pool = VirtualWorkerPool(workers=WORKERS)
    base_rep = FleetTuner(base_jobs, base_pool, store=base_store,
                          in_flight=WORKERS).run()
    base_util = base_rep.busy / max(base_rep.elapsed * WORKERS, 1e-12)
    util_ratio = base_util / max(service_util, 1e-12)

    cold_trials = [r["trials"] for r in cold_results.values()]
    repeat_trials = [r["trials"] for r in repeat_results]
    # per-key provenance: the daemon admits jobs as they arrive, so later
    # tenants can warm-start off earlier tenants' published artifacts —
    # a batch run() starts everything cold.  Informational, not a gate.
    base_by_key = {(r.job.split("/")[0], r.bucket, r.hardware): r
                   for r in base_rep.results}
    per_key = [
        {"key": [k, inp, hw],
         "service_runtime": cold_results[rid]["runtime"],
         "service_searcher": cold_results[rid]["searcher"],
         "service_warm_started": cold_results[rid]["warm_started"],
         "baseline_runtime": base_by_key[(k, inp, hw)].best_runtime}
        for rid, (_, k, inp, hw) in cold.items()]
    return {
        "tenants": 2 * len(keys),
        "keys": [list(k) for k in keys],
        "budget_per_job": budget,
        "cold_trials": cold_trials,
        "repeat_trials": repeat_trials,
        "all_cold_tuned": all(t == budget for t in cold_trials),
        "all_repeats_zero_trials": all(t == 0 for t in repeat_trials),
        "repeat_sources": [r["source"] for r in repeat_results],
        "service_utilization": service_util,
        "baseline_utilization": base_util,
        "utilization_ratio": util_ratio,
        "meets_utilization_target": util_ratio <= max_util_ratio,
        "per_key": per_key,
        "store_entries": stats["store_entries"],
        "tenant_ledger": stats["tenants"],
        "fleet_busy_s": fleet["busy_s"],
        "fleet_elapsed_s": fleet["elapsed_s"],
    }


def run_budgets(root: str, budget: int, seed: int) -> Dict:
    """One over-spender, one solvent tenant, one shared daemon."""
    d = _daemon(root, budget,
                tenants=TenantManager(max_active_per_tenant=1))
    try:
        with ServiceClient(d.address) as c:
            spend = c.submit_kernel("spender", "matmul", "tpu_v4",
                                    input="2048", budget=budget, seed=seed,
                                    tenant_budget_s=1e-7)
            # second request races the first job's completion: it either
            # queues (and must then PARK once the tenant is exhausted) or
            # bounces at submit with budget_exhausted — both are the
            # enforcement the service promises
            try:
                queued = c.submit_kernel("spender", "transpose", "tpu_v4",
                                         input="8192", budget=budget,
                                         seed=seed)
                second_outcome = "queued"
            except ServiceError as exc:
                queued, second_outcome = None, exc.code
            solvent = c.submit_kernel("bystander", "conv2d", "tpu_v5e",
                                      input="4096", budget=budget,
                                      seed=seed)
            first = c.result(spend["request_id"], timeout=300)
            other = c.result(solvent["request_id"], timeout=300)
            if queued is not None:
                for _ in range(200):
                    second_outcome = c.status(queued["request_id"])["state"]
                    if second_outcome == "parked":
                        break
                    time.sleep(0.02)
            # the exhausted tenant's next submit must bounce, always
            try:
                c.submit_kernel("spender", "matmul", "tpu_v4",
                                input="2048", budget=budget, seed=seed)
                rejected_code = None
            except ServiceError as exc:
                rejected_code = exc.code
            ledger = c.stats()["tenants"]
            c.shutdown(drain=True)
        d.wait(timeout=120)
    finally:
        d.pool.close()
    return {
        "budget_s": 1e-7,
        "spender_first_job_trials": first["trials"],
        "spender_spent_s": ledger["spender"]["spent_s"],
        "spender_exhausted": ledger["spender"]["exhausted"],
        "second_request_outcome": second_outcome,
        "resubmit_rejected_code": rejected_code,
        "bystander_trials": other["trials"],
        "bystander_unaffected": other["trials"] == budget
        and not ledger["bystander"]["exhausted"],
        "enforced": (ledger["spender"]["exhausted"]
                     and rejected_code == "budget_exhausted"
                     and second_outcome in ("parked", "budget_exhausted")),
    }


def run_serve_routing(root: str, seed: int) -> Dict:
    """OnlineAutotuner drift retune: via the daemon, then the fallback."""
    from repro.core.hwspec import get as hwget
    from repro.serve.autotune import (OnlineAutotuner, ServeWorkloadStats,
                                      SyntheticServeBackend, serve_space)
    from repro.serve.engine import Request
    from repro.tuning import ConfigStore

    hw = hwget("tpu_v4")
    stats = ServeWorkloadStats()
    rng = np.random.default_rng(seed)
    reqs = [Request(uid=i, prompt=rng.integers(1, 100, size=20),
                    max_new_tokens=8) for i in range(8)]

    def tick(service, timeout=30.0):
        backend = SyntheticServeBackend(hw, stats, seed=seed)
        tuner = OnlineAutotuner(
            backend, store=ConfigStore(), space=serve_space(), hw=hw,
            stats=stats, hardware_name="tpu_v4", max_live_trials=6,
            service=service, service_timeout=timeout)
        _, rep = tuner.serve(reqs)
        return backend, rep

    d = _daemon(root, budget=6)
    try:
        backend, rep = tick(f"127.0.0.1:{d.port}")
        with ServiceClient(d.address) as c:
            c.shutdown(drain=True)
        d.wait(timeout=120)
    finally:
        d.pool.close()
    fb_backend, fb_rep = tick("127.0.0.1:1", timeout=2.0)
    return {
        "via_service": rep.via_service,
        "engine_measure_calls_via_service": backend.measure_calls,
        "service_config": rep.config,
        "fallback_via_service": fb_rep.via_service,
        "fallback_live_trials": fb_rep.live_trials,
        "routed_with_zero_live_trials": rep.via_service
        and backend.measure_calls == 0,
        "fell_back_in_process": (not fb_rep.via_service
                                 and fb_rep.live_trials > 0
                                 and fb_backend.measure_calls
                                 == fb_rep.live_trials),
    }


def run_drain(root: str, seed: int) -> Dict:
    """Shutdown mid-tuning: partial progress collected, clean exit."""
    big_budget = 200
    d = _daemon(root, big_budget)
    try:
        with ServiceClient(d.address) as c:
            r = c.submit_kernel("t", "matmul", "tpu_v4", input="2048",
                                budget=big_budget, seed=seed,
                                searcher="random")
            c.shutdown(drain=True)
        clean = d.wait(timeout=120)
        rec = d._records[r["request_id"]]
    finally:
        d.pool.close()
    results = d.final_report.results if d.final_report else []
    return {
        "budget": big_budget,
        "clean_exit": clean,
        "request_state": rec.state,
        "partial_trials": rec.trials,
        "billed_s": rec.spent_s,
        "drained": (clean and rec.state in ("cancelled", "done")
                    and rec.trials < big_budget
                    and (rec.trials == 0 or rec.spent_s > 0.0)
                    and all(jr.cancelled or jr.trials == big_budget
                            for jr in results)),
    }


def run_benchmark(budget: int, seed: int, max_util_ratio: float) -> Dict:
    with tempfile.TemporaryDirectory() as td:
        multi = run_multi_tenant(os.path.join(td, "m"), budget, seed,
                                 max_util_ratio)
        budgets = run_budgets(os.path.join(td, "b"), budget, seed)
        serve = run_serve_routing(os.path.join(td, "s"), seed)
        drain = run_drain(os.path.join(td, "d"), seed)
    summary = {
        "tenants": multi["tenants"],
        "all_repeats_zero_trials": multi["all_repeats_zero_trials"],
        "utilization_ratio": multi["utilization_ratio"],
        "meets_utilization_target": multi["meets_utilization_target"],
        "budgets_enforced": budgets["enforced"],
        "bystander_unaffected": budgets["bystander_unaffected"],
        "serve_routed_zero_live": serve["routed_with_zero_live_trials"],
        "serve_fallback_ok": serve["fell_back_in_process"],
        "drain_ok": drain["drained"],
    }
    violations: List[str] = []
    if not multi["all_cold_tuned"]:
        violations.append("a cold tenant did not receive its full "
                          "trial budget")
    if not summary["all_repeats_zero_trials"]:
        violations.append(
            f"repeat-key tenants paid live trials: "
            f"{multi['repeat_trials']}")
    if not summary["meets_utilization_target"]:
        violations.append(
            f"service fleet utilization degraded "
            f"{summary['utilization_ratio']:.2f}x vs the single-job "
            f"fleet baseline (> {max_util_ratio}x)")
    if not summary["budgets_enforced"]:
        violations.append("tenant worker-seconds budget was not enforced "
                          "(no reject/park)")
    if not summary["bystander_unaffected"]:
        violations.append("budget enforcement disturbed a solvent tenant")
    if not summary["serve_routed_zero_live"]:
        violations.append("OnlineAutotuner --service retune was not "
                          "answered with zero live engine trials")
    if not summary["serve_fallback_ok"]:
        violations.append("OnlineAutotuner did not fall back in-process "
                          "with the daemon unreachable")
    if not summary["drain_ok"]:
        violations.append("graceful drain failed (lost progress or "
                          "unclean exit)")
    return {
        "schema": SCHEMA,
        "version": VERSION,
        "generated": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "host": {"python": platform.python_version(),
                 "numpy": np.__version__,
                 "machine": platform.machine()},
        "workload": {"kernels": [list(k) for k in KERNELS],
                     "hardware": list(HW), "workers": WORKERS,
                     "budget_per_job": budget, "seed": seed},
        "targets": {"max_util_ratio": max_util_ratio},
        "multi_tenant": multi,
        "budgets": budgets,
        "serve_routing": serve,
        "drain": drain,
        "summary": summary,
        "violations": violations,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--out", default="BENCH_service.json")
    ap.add_argument("--budget", type=int, default=16,
                    help="per-request trial budget for the cold tenants")
    ap.add_argument("--seed", type=int, default=11)
    ap.add_argument("--max-util-ratio", type=float, default=1.3,
                    help="max allowed baseline/service utilization ratio")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: smaller trial budgets")
    args = ap.parse_args(argv)

    budget = 10 if args.smoke else args.budget
    result = run_benchmark(budget, args.seed, args.max_util_ratio)

    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    s = result["summary"]
    print(f"wrote {args.out}")
    print(f"{s['tenants']} tenants over {len(result['multi_tenant']['keys'])}"
          f" keys: repeats zero-trial "
          f"{'PASS' if s['all_repeats_zero_trials'] else 'FAIL'}, "
          f"utilization ratio {s['utilization_ratio']:.3f}x "
          f"(target <= {args.max_util_ratio}x: "
          f"{'PASS' if s['meets_utilization_target'] else 'FAIL'})")
    print(f"budgets: enforced "
          f"{'PASS' if s['budgets_enforced'] else 'FAIL'}, bystander "
          f"unaffected {'PASS' if s['bystander_unaffected'] else 'FAIL'}")
    print(f"serve routing: via-service zero-live "
          f"{'PASS' if s['serve_routed_zero_live'] else 'FAIL'}, "
          f"fallback {'PASS' if s['serve_fallback_ok'] else 'FAIL'}")
    print(f"graceful drain: {'PASS' if s['drain_ok'] else 'FAIL'}")
    if result["violations"]:
        print("TARGETS VIOLATED:\n  " + "\n  ".join(result["violations"]),
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
